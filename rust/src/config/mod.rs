//! Experiment configuration: schemes, budgets, FL hyper-parameters
//! (paper Table II + Sec. V-B parameter lists). Scheme construction itself
//! lives in [`crate::compress::registry`] — this module derives a
//! [`SchemeSpec`] from the experiment budget and delegates.

pub mod presets;
pub mod scenario;

use std::sync::Arc;

use anyhow::Result;

use crate::compress::registry;
use crate::compress::{BlockCodec, Budget, Decoder, Encoder};
use crate::data::DatasetConfig;
use crate::quantizer::TableSource;
use crate::train::OptimizerKind;
use crate::util::json::Json;

pub use crate::compress::registry::{all_schemes, Scheme, SchemeSpec};
pub use scenario::{LatencyModel, ScenarioSpec};

/// Explicit scheme-construction overrides (from a `--scheme name:key=val`
/// spec string). Zero-valued fields mean "derive from the budget /
/// registry defaults" — see [`ExperimentConfig::scheme_spec`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct SchemeTuning {
    /// explicit sparsity level K
    pub k: usize,
    /// M22: pool tensors below this size into the global group
    pub min_fit: usize,
    /// count-sketch: table rows
    pub sketch_depth: usize,
    /// count-sketch operator seed
    pub seed: u64,
}

/// How a multi-PS cluster partitions the aggregation (ROADMAP: multi-PS
/// sharding; DESIGN.md §cluster).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PsMode {
    /// Model-parallel: each PS owns a contiguous dimension range of one
    /// global model, broadcasts only its slice, and aggregates only the
    /// survivors in its range. Bit-exact against a single PS.
    Range,
    /// Client-partitioned replicas: each PS owns a client subset and
    /// aggregates it on its own full-width replica, with periodic
    /// eq.-(7) averaging across replicas every `sync_every` rounds.
    Replica,
}

impl PsMode {
    pub fn parse(s: &str) -> Result<PsMode> {
        match s {
            "range" => Ok(PsMode::Range),
            "replica" => Ok(PsMode::Replica),
            other => anyhow::bail!("unknown --ps-mode `{other}` (range | replica)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            PsMode::Range => "range",
            PsMode::Replica => "replica",
        }
    }

    /// The mode's byte on the wire (the peer-membership frame announces the
    /// cluster mode to joining peers — DESIGN.md §peering).
    pub fn wire_code(&self) -> u8 {
        match self {
            PsMode::Range => 0,
            PsMode::Replica => 1,
        }
    }

    /// Inverse of [`PsMode::wire_code`].
    pub fn from_wire(code: u8) -> Result<PsMode> {
        match code {
            0 => Ok(PsMode::Range),
            1 => Ok(PsMode::Replica),
            other => anyhow::bail!("unknown ps-mode wire code {other}"),
        }
    }
}

/// Multi-PS cluster shape: how many `FedServer` instances one process
/// hosts behind a single reactor, and how they partition the work.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// number of parameter-server instances
    pub n_ps: usize,
    pub mode: PsMode,
    /// replica mode: eq.-(7) averaging cadence in rounds (1 = every
    /// round, 0 = only at end of run). Ignored by range mode, whose
    /// single global model never diverges.
    pub sync_every: usize,
    /// cross-process peering (DESIGN.md §peering): how many of the `n_ps`
    /// members live in *other processes* (`repro serve --peer ADDR`),
    /// joining over the wire protocol. The lead process hosts the
    /// remaining `n_ps - peers` members locally. 0 (the default) keeps
    /// the whole cluster in-process — the original PR-5 semantics.
    pub peers: usize,
    /// peering: the per-round sync-barrier deadline in milliseconds. A
    /// peer whose sub-step reply misses it is dropped from membership
    /// (its member's reduce runs locally, bit-exact) and counted in
    /// `ClusterStats`. 0 (the default) waits indefinitely, like the
    /// straggler deadline it reuses.
    pub barrier_timeout_ms: u64,
}

impl Default for ClusterConfig {
    fn default() -> ClusterConfig {
        ClusterConfig {
            n_ps: 2,
            mode: PsMode::Range,
            sync_every: 1,
            peers: 0,
            barrier_timeout_ms: 0,
        }
    }
}

impl ClusterConfig {
    /// Fluent construction over [`Default`], so call sites name only the
    /// knobs they change and new fields stop forcing struct-literal churn
    /// across sim/driver/fleet/tests.
    pub fn builder() -> ClusterConfigBuilder {
        ClusterConfigBuilder { cfg: ClusterConfig::default() }
    }
}

/// Builder for [`ClusterConfig`] — see [`ClusterConfig::builder`].
#[derive(Debug, Clone, Default)]
pub struct ClusterConfigBuilder {
    cfg: ClusterConfig,
}

impl ClusterConfigBuilder {
    pub fn n_ps(mut self, n: usize) -> Self {
        self.cfg.n_ps = n;
        self
    }

    pub fn mode(mut self, mode: PsMode) -> Self {
        self.cfg.mode = mode;
        self
    }

    pub fn sync_every(mut self, rounds: usize) -> Self {
        self.cfg.sync_every = rounds;
        self
    }

    pub fn peers(mut self, peers: usize) -> Self {
        self.cfg.peers = peers;
        self
    }

    pub fn barrier_timeout_ms(mut self, ms: u64) -> Self {
        self.cfg.barrier_timeout_ms = ms;
        self
    }

    pub fn build(self) -> ClusterConfig {
        self.cfg
    }
}

/// Parameter-server knobs for the `fedserve` subsystem (ROADMAP: scale the
/// PS loop past a handful of clients).
#[derive(Debug, Clone, PartialEq)]
pub struct ServerConfig {
    /// worker shards for the fused decode+reduce (1 = serial; parity with
    /// the serial eq.-(7) path is bit-exact at any count)
    pub shards: usize,
    /// explicit k-of-n participant sample per round; `None` derives k from
    /// `ExperimentConfig::participation`
    pub sampled_clients: Option<usize>,
    /// straggler deadline per round — uplinks arriving later are dropped
    /// (and counted) rather than stalling the round. 0 (the default) waits
    /// indefinitely, matching the original blocking driver so experiment
    /// results never depend on wall clock unless opted in.
    pub straggler_timeout_ms: u64,
    /// capacity of the shared LRU quantizer-table cache
    pub table_cache_capacity: usize,
    /// design the paper's (family, shape, rq) table grid at server start
    /// (ROADMAP: prewarm) so first-round uplinks never pay an LBG design
    pub prewarm: bool,
    /// persist the hot quantizer tables here at end of run and reload them
    /// at server start (ROADMAP: the cross-run half of the prewarm item);
    /// `None` (the default) keeps the cache in-memory only
    pub table_cache_path: Option<String>,
    /// host a multi-PS cluster instead of a single `FedServer` (ROADMAP:
    /// multi-PS sharding). `None` (the default) is the single-server loop;
    /// `Some` with `n_ps = 1` runs the cluster code path of one PS, which
    /// is bit-exact against the single server (the parity anchor).
    pub cluster: Option<ClusterConfig>,
    /// close the rate-adaptation loop at the PS (ROADMAP: online rate
    /// adaptation): fit the decoded-residual distribution each round,
    /// re-select the (family, m, rq) triple, and allocate per-client bit
    /// budgets from measured link rates. Off by default — a fixed scheme
    /// for the whole run, the original semantics.
    pub adaptive: bool,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            shards: 1,
            sampled_clients: None,
            straggler_timeout_ms: 0,
            table_cache_capacity: 256,
            prewarm: true,
            table_cache_path: None,
            cluster: None,
            adaptive: false,
        }
    }
}

impl ServerConfig {
    /// Fluent construction over [`Default`]:
    /// `ServerConfig::builder().shards(8).cluster(...).build()`. Call
    /// sites name only the knobs they change; plain field access on the
    /// built struct keeps working, so migration is incremental.
    pub fn builder() -> ServerConfigBuilder {
        ServerConfigBuilder { cfg: ServerConfig::default() }
    }
}

/// Builder for [`ServerConfig`] — see [`ServerConfig::builder`].
#[derive(Debug, Clone, Default)]
pub struct ServerConfigBuilder {
    cfg: ServerConfig,
}

impl ServerConfigBuilder {
    pub fn shards(mut self, shards: usize) -> Self {
        self.cfg.shards = shards;
        self
    }

    /// Explicit k-of-n sample per round (`None` derives from
    /// `participation`, the default).
    pub fn sampled_clients(mut self, k: Option<usize>) -> Self {
        self.cfg.sampled_clients = k;
        self
    }

    pub fn straggler_timeout_ms(mut self, ms: u64) -> Self {
        self.cfg.straggler_timeout_ms = ms;
        self
    }

    pub fn table_cache_capacity(mut self, cap: usize) -> Self {
        self.cfg.table_cache_capacity = cap;
        self
    }

    pub fn prewarm(mut self, on: bool) -> Self {
        self.cfg.prewarm = on;
        self
    }

    pub fn table_cache_path(mut self, path: impl Into<String>) -> Self {
        self.cfg.table_cache_path = Some(path.into());
        self
    }

    /// Host a multi-PS cluster (takes the built [`ClusterConfig`], so the
    /// two builders chain: `.cluster(ClusterConfig::builder()...build())`).
    pub fn cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cfg.cluster = Some(cluster);
        self
    }

    pub fn adaptive(mut self, on: bool) -> Self {
        self.cfg.adaptive = on;
        self
    }

    pub fn build(self) -> ServerConfig {
        self.cfg
    }
}

/// One full experiment run (one curve of one figure).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    pub arch: String,
    pub n_clients: usize,
    pub rounds: usize,
    /// local SGD/Adam steps per round ("one local epoch" in the paper)
    pub local_steps: usize,
    /// fraction of entries surviving topK (paper: 0.6)
    pub keep_frac: f64,
    /// bits per surviving entry (R_u / R_mw / r_sk)
    pub rq: u32,
    pub scheme: Scheme,
    /// explicit scheme-construction overrides (k, min_fit, sketch depth,
    /// operator seed) — zero fields derive from the budget; set by spec
    /// strings like `"tinyscript:k=5000"` or `"sketch:depth=5"`
    pub scheme_tuning: SchemeTuning,
    /// fraction of clients participating each round (paper Sec. IV-B
    /// extension: "partial clients are selected in each round")
    pub participation: f64,
    /// non-i.i.d. Dirichlet split parameter (None = i.i.d., paper default)
    pub dirichlet_alpha: Option<f64>,
    /// error-feedback memory (paper Sec. IV-B)
    pub memory: bool,
    pub memory_decay: f64,
    pub seed: u64,
    /// test batches used for eval each round (whole test set if usize::MAX)
    pub eval_batches: usize,
    pub dataset: DatasetConfig,
    /// fedserve parameter-server knobs (shards, sampling, deadlines, cache)
    pub server: ServerConfig,
}

impl ExperimentConfig {
    /// Defaults mirroring the paper's FL setting (Sec. II-D): 2 clients,
    /// i.i.d. split, report every local epoch.
    pub fn new(arch: &str, scheme: Scheme, rq: u32, rounds: usize) -> ExperimentConfig {
        ExperimentConfig {
            arch: arch.to_string(),
            n_clients: 2,
            rounds,
            local_steps: 4,
            keep_frac: 0.6,
            rq,
            scheme,
            scheme_tuning: SchemeTuning::default(),
            participation: 1.0,
            dirichlet_alpha: None,
            memory: false,
            memory_decay: 1.0,
            seed: 33,
            eval_batches: 4,
            dataset: DatasetConfig::default(),
            server: ServerConfig::default(),
        }
    }

    /// k of n: how many clients the server samples each round
    /// (`server.sampled_clients` wins over the `participation` fraction).
    pub fn participants_per_round(&self) -> usize {
        if self.n_clients == 0 {
            return 0;
        }
        self.server
            .sampled_clients
            .unwrap_or((self.participation * self.n_clients as f64).ceil() as usize)
            .clamp(1, self.n_clients)
    }

    pub fn optimizer(&self) -> Result<OptimizerKind> {
        OptimizerKind::preset(&self.arch)
    }

    /// The paper-style budget for this config at model dimension `d`.
    pub fn budget(&self, d: usize) -> Budget {
        let k_ref = ((self.keep_frac * d as f64).round() as usize).clamp(1, d);
        Budget { d, budget_bits: k_ref as u64 * self.rq as u64, k_ref, rq: self.rq }
    }

    /// The fully-resolved scheme spec for model dimension `d` — the single
    /// input to [`registry::build_encoder`] / [`registry::build_decoder`].
    /// Explicit [`SchemeTuning`] overrides win; zero fields derive from the
    /// budget and the registry defaults.
    pub fn scheme_spec(&self, d: usize) -> SchemeSpec {
        let t = self.scheme_tuning;
        let mut s = SchemeSpec::new(self.scheme, 0, t.k);
        if t.min_fit != 0 {
            s.min_fit = t.min_fit;
        }
        if t.sketch_depth != 0 {
            s.sketch_depth = t.sketch_depth;
        }
        s.seed = t.seed; // 0 = derive from the experiment seed in resolve()
        s.resolve(&self.budget(d), self.seed)
    }

    /// Build the scheme's client (encode) half for model dimension `d`.
    pub fn build_encoder(
        &self,
        d: usize,
        codec: Arc<dyn BlockCodec>,
        tables: Arc<dyn TableSource>,
    ) -> Result<Box<dyn Encoder>> {
        registry::build_encoder(&self.scheme_spec(d), codec, tables)
    }

    /// Build the scheme's server (decode) half for model dimension `d`.
    pub fn build_decoder(
        &self,
        d: usize,
        codec: Arc<dyn BlockCodec>,
        tables: Arc<dyn TableSource>,
    ) -> Result<Box<dyn Decoder>> {
        registry::build_decoder(&self.scheme_spec(d), codec, tables)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("arch", Json::from(self.arch.as_str())),
            ("n_clients", Json::from(self.n_clients)),
            ("rounds", Json::from(self.rounds)),
            ("local_steps", Json::from(self.local_steps)),
            ("keep_frac", Json::from(self.keep_frac)),
            ("rq", Json::from(self.rq as usize)),
            ("scheme", Json::from(self.scheme.label(self.rq).as_str())),
            ("memory", Json::from(self.memory)),
            ("seed", Json::from(self.seed as usize)),
            ("shards", Json::from(self.server.shards)),
            ("participants_per_round", Json::from(self.participants_per_round())),
            ("table_cache_capacity", Json::from(self.server.table_cache_capacity)),
            ("prewarm", Json::from(self.server.prewarm)),
            ("n_ps", Json::from(self.server.cluster.as_ref().map_or(0, |c| c.n_ps))),
            (
                "ps_mode",
                Json::from(self.server.cluster.as_ref().map_or("single", |c| c.mode.label())),
            ),
            ("adaptive", Json::from(self.server.adaptive)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::CpuCodec;
    use crate::quantizer::{Family, QuantizerTables};

    #[test]
    fn budget_uses_keep_frac() {
        let cfg = ExperimentConfig::new("cnn_s", Scheme::TopKUniform, 1, 5);
        let b = cfg.budget(552_874);
        assert_eq!(b.k_ref, 331_724);
        assert_eq!(b.budget_bits, 331_724);
    }

    #[test]
    fn factory_builds_every_scheme() {
        let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::new());
        let tables = Arc::new(QuantizerTables::new());
        for scheme in [
            Scheme::M22 { family: Family::GenNorm, m: 2.0 },
            Scheme::TinyScript,
            Scheme::TopKUniform,
            Scheme::TopKFp { bits: 8 },
            Scheme::TopKFp { bits: 4 },
            Scheme::CountSketch,
            Scheme::None,
        ] {
            let cfg = ExperimentConfig::new("cnn_s", scheme, 2, 3);
            let enc = cfg.build_encoder(10_000, codec.clone(), tables.clone()).unwrap();
            let dec = cfg.build_decoder(10_000, codec.clone(), tables.clone()).unwrap();
            assert!(!enc.name().is_empty());
            assert_eq!(enc.name(), dec.name());
        }
    }

    #[test]
    fn scheme_spec_resolution_and_tuning_overrides() {
        let mut cfg = ExperimentConfig::new("cnn_s", Scheme::TopKUniform, 2, 3);
        let spec = cfg.scheme_spec(10_000);
        assert_eq!(spec.rq, 2);
        assert_eq!(spec.k, cfg.budget(10_000).k_ref);
        assert_eq!(spec.seed, cfg.seed);
        cfg.scheme_tuning.k = 123;
        assert_eq!(cfg.scheme_spec(10_000).k, 123);
        // fp derives its own K from the bit budget
        cfg.scheme_tuning.k = 0;
        cfg.scheme = Scheme::TopKFp { bits: 8 };
        assert_eq!(cfg.scheme_spec(10_000).k, cfg.budget(10_000).k_fp(8));
        // min_fit / depth / seed overrides reach the resolved spec
        cfg.scheme = Scheme::CountSketch;
        cfg.scheme_tuning =
            SchemeTuning { k: 0, min_fit: 1024, sketch_depth: 5, seed: 99 };
        let spec = cfg.scheme_spec(10_000);
        assert_eq!(spec.min_fit, 1024);
        assert_eq!(spec.sketch_depth, 5);
        assert_eq!(spec.seed, 99);
    }

    #[test]
    fn participants_sampling_rules() {
        let mut cfg = ExperimentConfig::new("cnn_s", Scheme::TopKUniform, 1, 5);
        cfg.n_clients = 10;
        assert_eq!(cfg.participants_per_round(), 10); // participation 1.0
        cfg.participation = 0.25;
        assert_eq!(cfg.participants_per_round(), 3); // ceil(2.5)
        cfg.server.sampled_clients = Some(4);
        assert_eq!(cfg.participants_per_round(), 4); // explicit k wins
        cfg.server.sampled_clients = Some(99);
        assert_eq!(cfg.participants_per_round(), 10); // clamped to n
        cfg.server.sampled_clients = Some(0);
        assert_eq!(cfg.participants_per_round(), 1); // at least one
        cfg.n_clients = 0;
        assert_eq!(cfg.participants_per_round(), 0); // degenerate, no panic
    }

    #[test]
    fn server_defaults_are_conservative() {
        let s = ServerConfig::default();
        assert_eq!(s.shards, 1);
        assert_eq!(s.sampled_clients, None);
        assert_eq!(s.straggler_timeout_ms, 0); // wait forever, like the old driver
        assert!(s.table_cache_capacity > 0);
        assert!(s.prewarm); // startup cost, not a behavior change
        assert_eq!(s.cluster, None); // single PS unless asked
        assert!(!s.adaptive); // fixed scheme unless asked
    }

    #[test]
    fn ps_mode_parses_and_labels() {
        assert_eq!(PsMode::parse("range").unwrap(), PsMode::Range);
        assert_eq!(PsMode::parse("replica").unwrap(), PsMode::Replica);
        assert!(PsMode::parse("mesh").is_err());
        assert_eq!(PsMode::Range.label(), "range");
        assert_eq!(PsMode::Replica.label(), "replica");
        let c = ClusterConfig::default();
        assert_eq!(c.n_ps, 2);
        assert_eq!(c.sync_every, 1);
        // peering is opt-in: an in-process cluster by default
        assert_eq!(c.peers, 0);
        assert_eq!(c.barrier_timeout_ms, 0);
    }

    #[test]
    fn ps_mode_wire_codes_roundtrip() {
        for mode in [PsMode::Range, PsMode::Replica] {
            assert_eq!(PsMode::from_wire(mode.wire_code()).unwrap(), mode);
        }
        assert!(PsMode::from_wire(7).is_err());
    }

    #[test]
    fn builders_match_struct_literals() {
        // the builder must produce exactly what the equivalent struct
        // literal produces — it is sugar, not a second config semantics
        let built = ClusterConfig::builder()
            .n_ps(3)
            .mode(PsMode::Replica)
            .sync_every(4)
            .peers(2)
            .barrier_timeout_ms(1500)
            .build();
        let literal = ClusterConfig {
            n_ps: 3,
            mode: PsMode::Replica,
            sync_every: 4,
            peers: 2,
            barrier_timeout_ms: 1500,
        };
        assert_eq!(built, literal);

        let built = ServerConfig::builder()
            .shards(8)
            .sampled_clients(Some(16))
            .straggler_timeout_ms(250)
            .table_cache_capacity(99)
            .prewarm(false)
            .table_cache_path("/tmp/tables.bin")
            .cluster(literal.clone())
            .adaptive(true)
            .build();
        let literal = ServerConfig {
            shards: 8,
            sampled_clients: Some(16),
            straggler_timeout_ms: 250,
            table_cache_capacity: 99,
            prewarm: false,
            table_cache_path: Some("/tmp/tables.bin".to_string()),
            cluster: Some(literal),
            adaptive: true,
        };
        assert_eq!(built, literal);
        // untouched knobs stay at their Default
        assert_eq!(ServerConfig::builder().build(), ServerConfig::default());
        assert_eq!(ClusterConfig::builder().build(), ClusterConfig::default());
    }

    #[test]
    fn config_json_has_fields() {
        let cfg = ExperimentConfig::new("vgg_s", Scheme::TinyScript, 3, 7);
        let j = cfg.to_json();
        assert_eq!(j.get("arch").unwrap().as_str().unwrap(), "vgg_s");
        assert_eq!(j.get("rounds").unwrap().as_usize().unwrap(), 7);
    }
}
