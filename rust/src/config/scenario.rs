//! Fleet scenario specs: the one-line strings behind `repro fleet`.
//!
//! A [`ScenarioSpec`] describes a modeled client population — size,
//! Dirichlet-α label skew, join/leave churn, and the heavy-tailed
//! latency/bandwidth link model — in the same `name:key=val,...` grammar
//! as scheme specs, e.g. `fleet:n=1000000,alpha=0.1,churn=0.02,lat=lognorm`.
//! The spec is pure data: the fleet simulator (`fedserve::fleet`) derives
//! every per-client draw from `(seed, client)` RNG streams, so a scenario
//! string plus a seed replays bit-exactly.

use anyhow::{bail, ensure, Context, Result};

/// Per-client latency model of a scenario's links.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LatencyModel {
    /// every client at exactly `lat_ms` (the parity scenario)
    Fixed,
    /// heavy-tailed: `lat_ms · exp(jitter · N(0,1))` per client
    LogNormal,
}

impl LatencyModel {
    pub fn parse(s: &str) -> Result<LatencyModel> {
        match s {
            "fixed" => Ok(LatencyModel::Fixed),
            "lognorm" | "lognormal" => Ok(LatencyModel::LogNormal),
            other => bail!("unknown latency model `{other}` (fixed | lognorm)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LatencyModel::Fixed => "fixed",
            LatencyModel::LogNormal => "lognorm",
        }
    }
}

/// One fleet scenario: the modeled population and its heterogeneity knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// modeled population size (only sampled participants materialize)
    pub n: usize,
    /// Dirichlet-α label skew; `None` = IID data
    pub alpha: Option<f64>,
    /// per-round join/leave flip probability in [0, 1)
    pub churn: f64,
    pub lat: LatencyModel,
    /// median (lognorm) or exact (fixed) one-way latency in ms
    pub lat_ms: f64,
    /// lognormal σ for latency and bandwidth draws (0 = no jitter)
    pub jitter: f64,
    /// median uplink bandwidth in Mbit/s; 0 = infinite (latency only)
    pub bw_mbps: f64,
    /// label classes for the Dirichlet skew
    pub classes: usize,
    /// fleet seed; 0 = derive from the experiment seed
    pub seed: u64,
}

impl Default for ScenarioSpec {
    fn default() -> ScenarioSpec {
        ScenarioSpec {
            n: 1000,
            alpha: None,
            churn: 0.0,
            lat: LatencyModel::LogNormal,
            lat_ms: 50.0,
            jitter: 0.5,
            bw_mbps: 0.0,
            classes: 10,
            seed: 0,
        }
    }
}

impl ScenarioSpec {
    /// Parse a one-line scenario string: `fleet[:key=val,...]`.
    ///
    /// Keys: `n`, `alpha`, `churn`, `lat` (fixed | lognorm), `lat_ms`,
    /// `jitter`, `bw`/`bandwidth` (Mbit/s, 0 = infinite), `classes`,
    /// `seed`. Example: `fleet:n=1000000,alpha=0.1,churn=0.02,lat=lognorm`.
    pub fn parse(s: &str) -> Result<ScenarioSpec> {
        let (name, opts) = match s.split_once(':') {
            Some((n, o)) => (n, Some(o)),
            None => (s, None),
        };
        ensure!(name == "fleet", "unknown scenario `{name}` (expected `fleet:...`)");
        let mut spec = ScenarioSpec::default();
        let mut seen: Vec<&str> = Vec::new();
        if let Some(opts) = opts {
            for kv in opts.split(',') {
                let kv = kv.trim();
                if kv.is_empty() {
                    continue;
                }
                let (key, val) =
                    kv.split_once('=').with_context(|| format!("expected key=value in `{kv}`"))?;
                let val = val.trim();
                // a repeated key is a typo in a sweep script, not a
                // preference order — refuse instead of last-one-wins
                let canon = match key.trim() {
                    "bandwidth" => "bw",
                    other => other,
                };
                if seen.contains(&canon) {
                    bail!("duplicate scenario option `{}` in `{s}`", key.trim());
                }
                seen.push(canon);
                match key.trim() {
                    "n" => spec.n = val.parse().with_context(|| format!("bad n `{val}`"))?,
                    "alpha" => {
                        spec.alpha =
                            Some(val.parse().with_context(|| format!("bad alpha `{val}`"))?)
                    }
                    "churn" => {
                        spec.churn = val.parse().with_context(|| format!("bad churn `{val}`"))?
                    }
                    "lat" => spec.lat = LatencyModel::parse(val)?,
                    "lat_ms" => {
                        spec.lat_ms = val.parse().with_context(|| format!("bad lat_ms `{val}`"))?
                    }
                    "jitter" => {
                        spec.jitter = val.parse().with_context(|| format!("bad jitter `{val}`"))?
                    }
                    "bw" | "bandwidth" => {
                        spec.bw_mbps = val.parse().with_context(|| format!("bad bw `{val}`"))?
                    }
                    "classes" => {
                        spec.classes =
                            val.parse().with_context(|| format!("bad classes `{val}`"))?
                    }
                    "seed" => {
                        spec.seed = val.parse().with_context(|| format!("bad seed `{val}`"))?
                    }
                    other => bail!("unknown scenario option `{other}`"),
                }
            }
        }
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        ensure!(self.n > 0, "scenario n = 0");
        if let Some(a) = self.alpha {
            ensure!(a > 0.0 && a.is_finite(), "scenario alpha = {a} (must be finite and > 0)");
        }
        ensure!(
            (0.0..1.0).contains(&self.churn),
            "scenario churn = {} out of [0, 1)",
            self.churn
        );
        ensure!(
            self.lat_ms >= 0.0 && self.lat_ms.is_finite(),
            "scenario lat_ms = {} (must be finite and >= 0)",
            self.lat_ms
        );
        ensure!(
            self.jitter >= 0.0 && self.jitter.is_finite(),
            "scenario jitter = {} (must be finite and >= 0)",
            self.jitter
        );
        ensure!(
            self.bw_mbps >= 0.0 && self.bw_mbps.is_finite(),
            "scenario bw = {} (must be finite and >= 0)",
            self.bw_mbps
        );
        ensure!(self.classes > 0, "scenario classes = 0");
        Ok(())
    }

    /// The canonical one-line form: `parse(label())` round-trips (f64
    /// `Display` is shortest-roundtrip in Rust). Defaults that carry no
    /// information (`alpha` unset, infinite bandwidth, derived seed) are
    /// omitted.
    pub fn label(&self) -> String {
        let mut s = format!(
            "fleet:n={},churn={},lat={},lat_ms={},jitter={}",
            self.n,
            self.churn,
            self.lat.label(),
            self.lat_ms,
            self.jitter
        );
        if let Some(a) = self.alpha {
            s.push_str(&format!(",alpha={a}"));
        }
        if self.bw_mbps > 0.0 {
            s.push_str(&format!(",bw={}", self.bw_mbps));
        }
        if self.classes != 10 {
            s.push_str(&format!(",classes={}", self.classes));
        }
        if self.seed != 0 {
            s.push_str(&format!(",seed={}", self.seed));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bare_fleet_is_the_default_scenario() {
        let s = ScenarioSpec::parse("fleet").unwrap();
        assert_eq!(s, ScenarioSpec::default());
        assert_eq!(s.n, 1000);
        assert_eq!(s.alpha, None);
        assert_eq!(s.lat, LatencyModel::LogNormal);
        assert_eq!(s.bw_mbps, 0.0);
    }

    #[test]
    fn full_spec_string_parses_every_key() {
        let s = ScenarioSpec::parse(
            "fleet:n=1000000,alpha=0.1,churn=0.02,lat=lognorm,lat_ms=80,jitter=1.5,\
             bw=5,classes=100,seed=7",
        )
        .unwrap();
        assert_eq!(s.n, 1_000_000);
        assert_eq!(s.alpha, Some(0.1));
        assert_eq!(s.churn, 0.02);
        assert_eq!(s.lat, LatencyModel::LogNormal);
        assert_eq!(s.lat_ms, 80.0);
        assert_eq!(s.jitter, 1.5);
        assert_eq!(s.bw_mbps, 5.0);
        assert_eq!(s.classes, 100);
        assert_eq!(s.seed, 7);
        // alias + fixed model
        let s = ScenarioSpec::parse("fleet:lat=fixed,bandwidth=2").unwrap();
        assert_eq!(s.lat, LatencyModel::Fixed);
        assert_eq!(s.bw_mbps, 2.0);
    }

    #[test]
    fn spec_string_errors_name_the_offending_token() {
        let e = ScenarioSpec::parse("armada:n=5").unwrap_err();
        assert!(format!("{e:#}").contains("unknown scenario `armada`"), "{e:#}");
        let e = ScenarioSpec::parse("fleet:n=many").unwrap_err();
        assert!(format!("{e:#}").contains("bad n `many`"), "{e:#}");
        let e = ScenarioSpec::parse("fleet:n=5,n=6").unwrap_err();
        assert!(format!("{e:#}").contains("duplicate scenario option `n`"), "{e:#}");
        // `bandwidth` is an alias of `bw`: repeating across spellings dups
        let e = ScenarioSpec::parse("fleet:bw=1,bandwidth=2").unwrap_err();
        assert!(format!("{e:#}").contains("duplicate scenario option `bandwidth`"), "{e:#}");
        let e = ScenarioSpec::parse("fleet:warp=9").unwrap_err();
        assert!(format!("{e:#}").contains("unknown scenario option `warp`"), "{e:#}");
        let e = ScenarioSpec::parse("fleet:lat=quantum").unwrap_err();
        assert!(format!("{e:#}").contains("unknown latency model `quantum`"), "{e:#}");
        let e = ScenarioSpec::parse("fleet:n").unwrap_err();
        assert!(format!("{e:#}").contains("expected key=value"), "{e:#}");
    }

    #[test]
    fn validation_rejects_out_of_range_knobs() {
        assert!(ScenarioSpec::parse("fleet:n=0").is_err());
        assert!(ScenarioSpec::parse("fleet:alpha=0").is_err());
        assert!(ScenarioSpec::parse("fleet:alpha=-1").is_err());
        assert!(ScenarioSpec::parse("fleet:churn=1").is_err());
        assert!(ScenarioSpec::parse("fleet:churn=-0.5").is_err());
        assert!(ScenarioSpec::parse("fleet:lat_ms=-3").is_err());
        assert!(ScenarioSpec::parse("fleet:jitter=-1").is_err());
        assert!(ScenarioSpec::parse("fleet:bw=-2").is_err());
        assert!(ScenarioSpec::parse("fleet:classes=0").is_err());
        // boundary values that are legal
        assert!(ScenarioSpec::parse("fleet:churn=0,jitter=0,lat_ms=0,bw=0").is_ok());
    }

    #[test]
    fn label_round_trips_through_parse() {
        for s in [
            "fleet",
            "fleet:n=1000000,alpha=0.1,churn=0.02,lat=lognorm",
            "fleet:n=12,churn=0,lat=fixed,jitter=0",
            "fleet:n=400,lat=lognorm,jitter=1.5,lat_ms=80,bw=3.5,classes=17,seed=9",
        ] {
            let spec = ScenarioSpec::parse(s).unwrap();
            let back = ScenarioSpec::parse(&spec.label()).unwrap();
            assert_eq!(spec, back, "label `{}` of `{s}` did not round-trip", spec.label());
        }
    }
}
