//! Paper presets: Table II rows and the Sec. V-B scheme lists per figure.

use crate::quantizer::Family;

use super::{ExperimentConfig, Scheme};

/// Table II analogue, printable.
pub fn table2_rows() -> Vec<Vec<(&'static str, String)>> {
    let row = |arch: &'static str, opt: &str, lr: f64, batch: usize| {
        vec![
            ("Model", arch.to_string()),
            ("Dataset", "synthetic CIFAR-like (10 classes)".to_string()),
            ("Optimizer", opt.to_string()),
            ("Learning Rate", format!("{lr}")),
            ("Momentum", "0".to_string()),
            ("Loss", "Categorical Cross Entropy".to_string()),
            ("Mini-Batch Size", format!("{batch}")),
        ]
    };
    vec![
        row("cnn_s", "SGD", 0.01, 32),
        row("resnet_s", "Adam", 0.001, 32),
        row("vgg_s", "Adam", 0.0005, 32),
    ]
}

/// Fig. 3 scheme list at a given quantizer rate (paper Sec. V-B params).
/// The (M-per-rate) pairs follow the paper: at R=1 → G2/G3, W4;
/// at R=3 → G2/G9, W7.
pub fn fig3_schemes(rq: u32) -> Vec<Scheme> {
    let (g_hi, w_m) = match rq {
        1 => (3.0, 4.0),
        2 => (6.0, 5.0),
        _ => (9.0, 7.0),
    };
    vec![
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::GenNorm, m: g_hi },
        Scheme::TinyScript,
        Scheme::M22 { family: Family::Weibull, m: w_m },
        Scheme::CountSketch,
    ]
}

/// Fig. 4 M sweep (paper: dR = 664k ⇒ R = 2 bits/nonzero).
pub fn fig4_ms() -> Vec<f64> {
    vec![0.0, 2.0, 4.0, 6.0, 8.0]
}

/// Fig. 5 left: the three non-uniform schemes on ResNet.
pub fn fig5a_schemes() -> Vec<Scheme> {
    vec![
        Scheme::CountSketch,
        Scheme::TinyScript,
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
    ]
}

/// Fig. 5 right: no-quantization vs M22 at four budgets (R = 1..4).
pub fn fig5b_rates() -> Vec<u32> {
    vec![1, 2, 3, 4]
}

/// A quick-running default experiment (examples / smoke).
pub fn quickstart(arch: &str, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new(
        arch,
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        2,
        rounds,
    );
    cfg.dataset.train_per_class = 64;
    cfg.dataset.test_per_class = 16;
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_has_eight_curves_like_the_paper() {
        for rq in [1u32, 3] {
            assert_eq!(fig3_schemes(rq).len(), 8);
        }
        // rate-adapted M choices (paper: larger M at looser budget)
        assert!(fig3_schemes(3).contains(&Scheme::M22 { family: Family::GenNorm, m: 9.0 }));
        assert!(fig3_schemes(1).contains(&Scheme::M22 { family: Family::GenNorm, m: 3.0 }));
    }

    #[test]
    fn table2_covers_all_models() {
        let rows = table2_rows();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0][2].1, "SGD");
        assert_eq!(rows[1][2].1, "Adam");
    }

    #[test]
    fn fig4_and_fig5_presets() {
        assert_eq!(fig4_ms(), vec![0.0, 2.0, 4.0, 6.0, 8.0]);
        assert_eq!(fig5a_schemes().len(), 3);
        assert_eq!(fig5b_rates(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn quickstart_is_small() {
        let q = quickstart("cnn_s", 3);
        assert!(q.dataset.train_per_class <= 64);
        assert_eq!(q.rounds, 3);
    }
}
