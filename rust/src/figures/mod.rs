//! Figure/table regeneration — one function per paper exhibit.
//!
//! Each returns CSV text (plus prints a short summary) so the CLI
//! (`repro figN`), the benches, and EXPERIMENTS.md all share one
//! implementation. Scale knobs (`FigScale`) let benches shrink rounds /
//! dataset while keeping the paper's structure.

use anyhow::Result;

use crate::compress::topk::topk;
use crate::config::{presets, ExperimentConfig, Scheme};
use crate::coordinator::run_experiment;
use crate::data::{Dataset, DatasetConfig};
use crate::metrics::{per_bit_accuracy, PerBitInput, Recorder};
use crate::quantizer::{design, Family};
use crate::stats::fitting::{
    fit_gaussian, fit_gennorm, fit_laplace, fit_weibull2, ks_statistic, mean_nll, Moments,
};
use crate::stats::histogram::Histogram;
use crate::stats::{Distribution, GenNorm};
use crate::runtime::RuntimeHandle;
use crate::train::Manifest;

/// Experiment scale: full (CLI default) vs smoke (benches/tests).
#[derive(Debug, Clone, Copy)]
pub struct FigScale {
    pub rounds: usize,
    pub train_per_class: usize,
    pub test_per_class: usize,
    pub local_steps: usize,
    pub eval_batches: usize,
    pub seeds: usize,
}

impl FigScale {
    pub fn full() -> Self {
        FigScale {
            rounds: 30,
            train_per_class: 200,
            test_per_class: 40,
            local_steps: 4,
            eval_batches: 8,
            seeds: 2,
        }
    }

    pub fn smoke() -> Self {
        FigScale {
            rounds: 3,
            train_per_class: 48,
            test_per_class: 8,
            local_steps: 2,
            eval_batches: 2,
            seeds: 1,
        }
    }

    fn apply(&self, cfg: &mut ExperimentConfig) {
        cfg.rounds = self.rounds;
        cfg.local_steps = self.local_steps;
        cfg.eval_batches = self.eval_batches;
        cfg.dataset.train_per_class = self.train_per_class;
        cfg.dataset.test_per_class = self.test_per_class;
    }
}

/// Run one scheme, seed-averaged (the paper averages 5 inits; we default 2).
fn run_averaged(
    cfg: &ExperimentConfig,
    runtime: &RuntimeHandle,
    dataset: &Dataset,
    series: &str,
    seeds: usize,
    rec: &mut Recorder,
) -> Result<f64> {
    let mut per_seed = Vec::new();
    for s in 0..seeds {
        let mut c = cfg.clone();
        c.seed = cfg.seed + s as u64 * 101;
        let mut tmp = Recorder::new();
        let out = run_experiment(&c, runtime, dataset, series, &mut tmp)?;
        per_seed.push((tmp, out.final_test_acc));
    }
    // average the curves across seeds into the shared recorder
    let n = per_seed.len();
    let rounds = cfg.rounds;
    for r in 0..rounds {
        let rows: Vec<&crate::metrics::Row> =
            per_seed.iter().map(|(t, _)| &t.rows[r]).collect();
        rec.push(crate::metrics::Row {
            series: series.to_string(),
            round: r,
            train_loss: rows.iter().map(|x| x.train_loss).sum::<f64>() / n as f64,
            test_loss: rows.iter().map(|x| x.test_loss).sum::<f64>() / n as f64,
            test_acc: rows.iter().map(|x| x.test_acc).sum::<f64>() / n as f64,
            bits_up: rows.iter().map(|x| x.bits_up).sum::<f64>() / n as f64,
        });
    }
    Ok(per_seed.iter().map(|(_, a)| a).sum::<f64>() / n as f64)
}

// ---------------------------------------------------------------------------
// Table I / Table II
// ---------------------------------------------------------------------------

/// Table I analogue: per-model parameter summary from the manifest.
pub fn table1(manifest: &Manifest) -> String {
    let mut s = String::from(
        "Table I — model parameter summary (reproduction scale)\n\
         architecture | tensors | total params | conv params | dense params\n",
    );
    for m in &manifest.models {
        s.push_str(&format!(
            "{:<12} | {:>7} | {:>12} | {:>11} | {:>12}\n",
            m.arch,
            m.tensors.len(),
            m.total_params,
            m.conv_params,
            m.dense_params
        ));
    }
    s
}

/// Table II analogue: training hyper-parameters.
pub fn table2() -> String {
    let mut s = String::from("Table II — training hyper-parameters\n");
    for row in presets::table2_rows() {
        for (k, v) in &row {
            s.push_str(&format!("{k:<18}: {v}\n"));
        }
        s.push('\n');
    }
    s
}

// ---------------------------------------------------------------------------
// Fig. 1 — gradient distribution fitting at two sparsification levels
// ---------------------------------------------------------------------------

/// Train the CNN briefly, grab a conv-layer gradient at iteration ~10, topK
/// it at 90% / 40% retention, fit all four families, and emit histogram +
/// fitted-pdf series (CSV) plus NLL/KS scores.
pub fn fig1(runtime: &RuntimeHandle, scale: FigScale) -> Result<String> {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir)?;
    let arch = "cnn_s";
    let spec = manifest.model(arch)?;
    let mut w = manifest.load_init(&dir, arch)?;
    let ds = Dataset::generate(DatasetConfig {
        train_per_class: scale.train_per_class,
        test_per_class: scale.test_per_class,
        ..Default::default()
    });
    // 10 plain SGD iterations (the paper: "CNN, layer 42, iteration 10")
    let mut grads = vec![0.0f32; spec.d()];
    for i in 0..10 {
        let b = ds.batch(&ds.train, i * runtime.batch, runtime.batch);
        let step = runtime.train_step(arch, &w, &b.x, &b.y)?;
        for (wi, gi) in w.iter_mut().zip(&step.grads) {
            *wi -= 0.01 * gi;
        }
        grads = step.grads;
    }
    // the large conv tensor = "layer 42" analogue
    let conv = spec
        .tensors
        .iter()
        .filter(|t| t.kind == crate::train::TensorKind::Conv)
        .max_by_key(|t| t.size)
        .expect("a conv tensor");
    let layer = &grads[conv.offset..conv.offset + conv.size];

    let mut csv = String::from(
        "panel,x,empirical_density,gauss,laplace,gennorm,dweibull\n",
    );
    let mut summary = String::new();
    for (panel, keep_frac) in [("keep90", 0.9), ("keep40", 0.4)] {
        let k = ((keep_frac * layer.len() as f64) as usize).max(2);
        let (sparse, _) = topk(layer, k);
        let m = Moments::from_nonzeros(&sparse)?;
        let gauss = fit_gaussian(&m);
        let lap = fit_laplace(&m);
        let gn = fit_gennorm(&m);
        let wb = fit_weibull2(&m);
        let hist = Histogram::spanning(&sparse, 61);
        for b in 0..hist.bins() {
            let x = hist.center(b);
            csv.push_str(&format!(
                "{panel},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},{:.6e}\n",
                x,
                hist.density(b),
                gauss.pdf(x),
                lap.pdf(x),
                gn.pdf(x),
                wb.pdf(x),
            ));
        }
        summary.push_str(&format!(
            "# {panel}: beta={:.3} c={:.3} | NLL g={:.3} l={:.3} gn={:.3} w={:.3} | KS g={:.3} l={:.3} gn={:.3} w={:.3}\n",
            gn.beta,
            wb.c,
            mean_nll(&gauss, &sparse),
            mean_nll(&lap, &sparse),
            mean_nll(&gn, &sparse),
            mean_nll(&wb, &sparse),
            ks_statistic(&gauss, &sparse),
            ks_statistic(&lap, &sparse),
            ks_statistic(&gn, &sparse),
            ks_statistic(&wb, &sparse),
        ));
    }
    print!("{summary}");
    Ok(csv + &summary)
}

// ---------------------------------------------------------------------------
// Fig. 2 — quantization centers/thresholds vs M (GenNorm)
// ---------------------------------------------------------------------------

/// Pure quantizer-design sweep: unit-variance GenNorm, M ∈ [0, 8], 8 levels
/// (positive region shown, as in the paper).
pub fn fig2() -> String {
    let mut csv = String::from("m,kind,index,value\n");
    let dist = GenNorm::standardized(1.0);
    for mi in 0..=16 {
        let m = mi as f64 * 0.5;
        let q = design(&dist, m, 8);
        for (i, c) in q.centers.iter().enumerate().skip(4) {
            csv.push_str(&format!("{m},center,{},{:.6}\n", i - 4, c));
        }
        for (i, t) in q.thresholds.iter().enumerate().skip(4) {
            csv.push_str(&format!("{m},threshold,{},{:.6}\n", i - 4, t));
        }
    }
    csv
}

// ---------------------------------------------------------------------------
// Fig. 3 — all schemes, accuracy vs round, at a budget
// ---------------------------------------------------------------------------

pub fn fig3(runtime: &RuntimeHandle, rq: u32, scale: FigScale) -> Result<(Recorder, String)> {
    let mut rec = Recorder::new();
    let mut cfg0 = ExperimentConfig::new("cnn_s", Scheme::TopKUniform, rq, scale.rounds);
    scale.apply(&mut cfg0);
    let dataset = Dataset::generate(cfg0.dataset);
    let mut summary = format!("# Fig. 3 (R={rq}): final accuracy per scheme\n");
    for scheme in presets::fig3_schemes(rq) {
        let mut cfg = cfg0.clone();
        cfg.scheme = scheme;
        let label = scheme.label(rq);
        let acc = run_averaged(&cfg, runtime, &dataset, &label, scale.seeds, &mut rec)?;
        summary.push_str(&format!("#   {label:<24} acc={acc:.4}\n"));
    }
    print!("{summary}");
    Ok((rec, summary))
}

// ---------------------------------------------------------------------------
// Fig. 4 — the effect of M (GenNorm, R = 2)
// ---------------------------------------------------------------------------

pub fn fig4(runtime: &RuntimeHandle, scale: FigScale) -> Result<(Recorder, String)> {
    let mut rec = Recorder::new();
    let mut cfg0 = ExperimentConfig::new("cnn_s", Scheme::TopKUniform, 2, scale.rounds);
    scale.apply(&mut cfg0);
    let dataset = Dataset::generate(cfg0.dataset);
    let mut summary = String::from("# Fig. 4 (R=2): M sweep, GenNorm\n");
    for m in presets::fig4_ms() {
        let mut cfg = cfg0.clone();
        cfg.scheme = Scheme::M22 { family: Family::GenNorm, m };
        let label = format!("M={m}");
        let acc = run_averaged(&cfg, runtime, &dataset, &label, scale.seeds, &mut rec)?;
        summary.push_str(&format!("#   {label:<6} acc={acc:.4}\n"));
    }
    print!("{summary}");
    Ok((rec, summary))
}

// ---------------------------------------------------------------------------
// Fig. 5 — other architectures
// ---------------------------------------------------------------------------

/// Left panel: ResNet, the three non-uniform schemes.
pub fn fig5a(runtime: &RuntimeHandle, scale: FigScale) -> Result<(Recorder, String)> {
    let mut rec = Recorder::new();
    let mut cfg0 = ExperimentConfig::new("resnet_s", Scheme::TopKUniform, 2, scale.rounds);
    scale.apply(&mut cfg0);
    let dataset = Dataset::generate(cfg0.dataset);
    let mut summary = String::from("# Fig. 5 left (ResNet): non-uniform schemes\n");
    for scheme in presets::fig5a_schemes() {
        let mut cfg = cfg0.clone();
        cfg.scheme = scheme;
        let label = scheme.label(cfg.rq);
        let acc = run_averaged(&cfg, runtime, &dataset, &label, scale.seeds, &mut rec)?;
        summary.push_str(&format!("#   {label:<24} acc={acc:.4}\n"));
    }
    print!("{summary}");
    Ok((rec, summary))
}

/// Right panel: VGG, no-quantization vs M22 at four budgets; also reports
/// the per-bit accuracy (eq. 9) of each budget against the uncompressed run.
pub fn fig5b(runtime: &RuntimeHandle, scale: FigScale) -> Result<(Recorder, String)> {
    let mut rec = Recorder::new();
    let mut cfg0 = ExperimentConfig::new("vgg_s", Scheme::None, 4, scale.rounds);
    scale.apply(&mut cfg0);
    let dataset = Dataset::generate(cfg0.dataset);
    let mut summary = String::from("# Fig. 5 right (VGG): no-quant vs M22 budgets\n");
    let base_label = "no quantization";
    let base_acc =
        run_averaged(&cfg0, runtime, &dataset, base_label, scale.seeds, &mut rec)?;
    let base_loss = rec.final_loss(base_label).unwrap();
    summary.push_str(&format!("#   {base_label:<24} acc={base_acc:.4}\n"));
    for rq in presets::fig5b_rates() {
        let mut cfg = cfg0.clone();
        cfg.rq = rq;
        cfg.scheme = Scheme::M22 { family: Family::GenNorm, m: if rq >= 3 { 6.0 } else { 2.0 } };
        let label = format!("M22 (R={rq})");
        let acc = run_averaged(&cfg, runtime, &dataset, &label, scale.seeds, &mut rec)?;
        let bits = rec.total_bits(&label) / cfg.rounds as f64;
        let delta = per_bit_accuracy(&PerBitInput {
            reference_final: base_loss,
            compressed_final: rec.final_loss(&label).unwrap(),
            bits_per_round: bits,
            rounds: cfg.rounds,
        });
        summary.push_str(&format!(
            "#   {label:<24} acc={acc:.4} per-bit Δ(T,R)={delta:+.3e}\n"
        ));
    }
    print!("{summary}");
    Ok((rec, summary))
}
