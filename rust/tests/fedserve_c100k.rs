//! Integration: the reactor at C10K scale.
//!
//! The C100K issue's headline claim is that one server thread can hold ten
//! thousand mostly-idle connections without the event loop charging per
//! *registered* socket. This suite drives the real `TcpServerTransport`
//! (edge-triggered epoll by default, level-triggered `poll(2)` under
//! `--features force-poll`) with 10k loopback clients of which only 64
//! ever speak, and pins the three scaling properties:
//!
//! * the straggler deadline still lands within 10 ms — timer accuracy
//!   does not degrade with fan-in;
//! * `TransportStats.wakeups` stays a small constant per round — cost is
//!   O(ready), not O(registered);
//! * the buffer pool performs **zero** new allocations in steady-state
//!   rounds — every uplink lands in a page taken at accept time.
//!
//! The spin fallback naps once per millisecond by design (its wakeups ARE
//! O(deadline)), so this file is compiled out under `spin-poll`; the spin
//! CI lane runs the ordinary reactor suite instead.
#![cfg(all(unix, not(feature = "spin-poll")))]

use std::net::TcpListener;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use m22::compress::{encode_once, NoCompression};
use m22::config::ServerConfig;
use m22::coordinator::Uplink;
use m22::fedserve::sim::sim_spec;
use m22::fedserve::transport::{ClientTransport, TcpClientTransport, TcpServerTransport, Transport};
use m22::fedserve::wire;
use m22::fedserve::FedServer;

/// Dialing 10k sockets sequentially takes a while on a loaded runner.
const NET_TIMEOUT: Duration = Duration::from_secs(120);

#[test]
#[ignore = "10k sockets + a 10 ms timing budget: run serially — CI does \
            `--include-ignored --test-threads=1` in the c100k lane"]
fn ten_thousand_idle_connections_cost_nothing_per_round() {
    let want = 10_000u64;
    // one server end + one client end per connection, plus listener /
    // epoll fd / stdio slack — size off the limit we actually got, and
    // skip (don't fail) on boxes too constrained to say anything useful
    let soft = match pollshim::raise_nofile(2 * want + 512) {
        Ok(soft) => soft,
        Err(e) => {
            eprintln!("c10k smoke skipped: cannot query RLIMIT_NOFILE: {e}");
            return;
        }
    };
    let n = (want.min(soft.saturating_sub(512) / 2)) as usize;
    if n < 1_024 {
        eprintln!("c10k smoke skipped: RLIMIT_NOFILE {soft} leaves only {n} connections");
        return;
    }
    let responders = 64usize;
    let d = 32usize;
    let deadline_ms = 250u64;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    let (resp_tx, resp_rx) = mpsc::channel::<Vec<TcpClientTransport>>();
    std::thread::scope(|scope| {
        // one helper dials every socket; ids 0..responders are handed to
        // the responder thread, the rest stay open and silent until released
        {
            let addr = addr.clone();
            scope.spawn(move || {
                let mut resp = Vec::with_capacity(responders);
                let mut held = Vec::with_capacity(n - responders);
                for id in 0..n {
                    let t = TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap();
                    if id < responders {
                        resp.push(t);
                    } else {
                        held.push(t);
                    }
                }
                let _ = resp_tx.send(resp);
                let _ = release_rx.recv();
                drop(held);
            });
        }
        // the speakers: answer every round until the server says shutdown
        {
            let spec = &spec;
            scope.spawn(move || {
                let Ok(mut resp) = resp_rx.recv() else { return };
                'rounds: loop {
                    for (id, t) in resp.iter_mut().enumerate() {
                        match t.recv() {
                            Ok(Some(wire::Message::Round { round, .. })) => {
                                let g = vec![(id + 1) as f32; d];
                                let (payload, _, report) =
                                    encode_once(&NoCompression, &g, spec).unwrap();
                                let up = Uplink {
                                    client_id: id,
                                    round,
                                    payload,
                                    report,
                                    train_loss: 0.0,
                                    error: None,
                                };
                                let f = wire::encode_update(&up);
                                if t.send(&f).is_err() {
                                    break 'rounds;
                                }
                            }
                            _ => break 'rounds, // shutdown or server-side close
                        }
                    }
                }
            });
        }

        let mut transport = TcpServerTransport::accept(&listener, n, NET_TIMEOUT).unwrap();
        let backend = transport.stats().backend;
        assert!(
            backend == "epoll" || backend == "poll",
            "unexpected backend {backend:?} (spin is compiled out of this file)"
        );
        let cfg = ServerConfig { straggler_timeout_ms: deadline_ms, ..Default::default() };
        let mut server = FedServer::new(cfg, n, 1, Box::new(NoCompression));
        let participants: Vec<usize> = (0..n).collect();
        let mut w = vec![0.0f32; d];
        let lo = Duration::from_millis(deadline_ms);

        // warmup round: faults in every per-connection read page and the
        // lazy bits (outbound queues, session state) so the measured
        // rounds below see the steady state
        let s0 = server.run_round(0, &participants, &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s0.received, responders);
        assert_eq!(s0.dropped, n - responders);

        let mut best_late: Option<Duration> = None;
        for round in 1..=3usize {
            let before = transport.stats();
            let t0 = Instant::now();
            let s = server.run_round(round, &participants, &mut transport, &spec, &mut w).unwrap();
            let elapsed = t0.elapsed();
            let after = transport.stats();
            assert_eq!(s.received, responders, "round {round}");
            assert_eq!(s.dropped, n - responders, "round {round}");
            // ending EARLY is a correctness bug, full stop
            assert!(
                elapsed >= lo,
                "round {round} ended {elapsed:?} before the {deadline_ms} ms deadline"
            );
            let late = elapsed - lo;
            best_late = Some(best_late.map_or(late, |b| b.min(late)));
            // O(ready), not O(registered): 64 speakers plus one deadline
            // park must not cost anywhere near one wakeup per idle socket
            let wakeups = after.wakeups - before.wakeups;
            assert!(
                wakeups < 512,
                "round {round}: {wakeups} wakeups for {responders} speakers among {n} connections"
            );
            // steady state: every uplink lands in a page pooled at accept
            // time; growth here means the hot path allocates per round
            assert_eq!(
                after.pool_allocs, before.pool_allocs,
                "round {round}: buffer pool grew in steady state"
            );
        }
        // lateness on a shared runner is scheduling noise: requiring the
        // BEST of three measured rounds inside the budget damps the flake
        // without weakening the bound (same idea as the one-retry in the
        // 256-connection deadline test)
        let best = best_late.unwrap();
        assert!(
            best < Duration::from_millis(10),
            "deadline error {best:?} ≥ 10 ms in all three measured rounds at {n} connections"
        );
        let ts = transport.stats();
        assert_eq!(ts.disconnects, 0, "nobody hung up during the measured rounds");
        assert_eq!(ts.decode_errors, 0);
        release_tx.send(()).unwrap();
        transport.close().unwrap();
    });
}
