//! Integration: the fedserve reactor under load.
//!
//! PR 3 proved the TCP transport moves bytes without touching numerics;
//! this suite proves the *reactor* rewrite (one `poll(2)` readiness loop
//! multiplexing every connection, timer-wheel deadlines, per-connection
//! outbound queues) keeps that contract while scaling to hundreds of
//! connections on a single server thread:
//!
//! * bit parity vs the threaded channel path for every registry scheme —
//!   the readiness loop reorders *waits*, never bytes;
//! * straggler-deadline accuracy at 256 live connections: the round ends
//!   within 10 ms of the configured deadline, and (on real `poll(2)`) in a
//!   handful of wakeups, not a 1 ms-spin's hundreds;
//! * a mid-round disconnect storm — a third of the fleet hangs up, a third
//!   sends garbage — must degrade (drops + attributed decode errors),
//!   never abort, and the next round must still complete on the healthy
//!   remainder;
//! * a 128-client loopback run through the full `simulate_with` path.

use std::net::TcpListener;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use m22::compress::{encode_once, NoCompression};
use m22::config::{ExperimentConfig, Scheme, ServerConfig};
use m22::coordinator::Uplink;
use m22::fedserve::sim::{sim_spec, simulate_with, TransportMode};
use m22::fedserve::transport::{ClientTransport, TcpClientTransport, TcpServerTransport, Transport};
use m22::fedserve::wire;
use m22::fedserve::FedServer;
use m22::quantizer::Family;

const NET_TIMEOUT: Duration = Duration::from_secs(30);

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

#[test]
fn reactor_bit_parity_with_the_threaded_channel_path_for_every_scheme() {
    let d = 900;
    for scheme in [
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ] {
        let mut cfg = ExperimentConfig::new("sim", scheme, 2, 2);
        cfg.n_clients = 4;
        cfg.server.shards = 2;
        cfg.server.straggler_timeout_ms = 30_000;
        let chan = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
        let tcp = simulate_with(&cfg, d, TransportMode::TcpLoopback).unwrap();
        assert_bitwise_eq(&chan.w, &tcp.w, &format!("{scheme:?}"));
        assert!(chan.w.iter().any(|&x| x != 0.0), "{scheme:?}: run did nothing");
        // both transports went through the reactor loop...
        assert!(tcp.stats.transport.wakeups > 0, "{scheme:?}");
        assert!(chan.stats.transport.wakeups > 0, "{scheme:?}");
        // ...and a clean run loses nobody
        assert_eq!(tcp.stats.transport.disconnects, 0, "{scheme:?}");
        assert_eq!(tcp.stats.transport.decode_errors, 0, "{scheme:?}");
        assert_eq!(tcp.stats.total_dropped(), 0, "{scheme:?}");
    }
}

#[test]
#[ignore = "timing-sensitive (10 ms budget): run serially — CI does \
            `--include-ignored --test-threads=1` in the reactor lane"]
fn straggler_deadline_is_accurate_at_256_connections() {
    let n = 256usize;
    let d = 64usize;
    let deadline_ms = 250u64;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    std::thread::scope(|scope| {
        // one helper holds all 256 client sockets open and silent — every
        // sampled participant is a straggler, so the round must run the
        // full deadline and not a poll-granularity more
        scope.spawn(move || {
            let mut held = Vec::with_capacity(n);
            for id in 0..n {
                held.push(TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap());
            }
            let _ = release_rx.recv();
            drop(held);
        });

        let mut transport = TcpServerTransport::accept(&listener, n, NET_TIMEOUT).unwrap();
        let cfg = ServerConfig { straggler_timeout_ms: deadline_ms, ..Default::default() };
        let mut server = FedServer::new(cfg, n, 1, Box::new(NoCompression));
        let participants: Vec<usize> = (0..n).collect();
        let mut w = vec![0.0f32; d];
        let lo = Duration::from_millis(deadline_ms);
        // the real poll(2) path owes ISSUE-level precision; the spin
        // fallback's 1 ms-tick granularity gets the old loop's slack
        let budget = if cfg!(feature = "spin-poll") { 25 } else { 10 };
        let hi = lo + Duration::from_millis(budget);
        // ending EARLY is a correctness bug and fails immediately; ending
        // late can be shared-runner scheduling noise, so one retry damps
        // the flake without weakening the bound
        let mut late = None;
        for attempt in 0..2 {
            let t0 = Instant::now();
            let s = server
                .run_round(attempt, &participants, &mut transport, &spec, &mut w)
                .unwrap();
            let elapsed = t0.elapsed();
            assert_eq!(s.received, 0);
            assert_eq!(s.dropped, n);
            assert!(elapsed >= lo, "round ended {elapsed:?} before the {deadline_ms} ms deadline");
            if elapsed <= hi {
                late = None;
                break;
            }
            late = Some(elapsed - lo);
        }
        if let Some(err) = late {
            panic!("deadline error {err:?} exceeds {budget} ms at {n} connections (twice)");
        }
        // real poll(2) parks once until the deadline; a sleep-spin would
        // have burned ~one wakeup per millisecond
        #[cfg(not(feature = "spin-poll"))]
        assert!(
            transport.stats().wakeups < 32,
            "reactor woke {} times for one idle round",
            transport.stats().wakeups
        );
        release_tx.send(()).unwrap();
        transport.close().unwrap();
    });
}

#[test]
fn disconnect_storm_degrades_and_never_aborts() {
    // 64 clients: 22 healthy, 21 hang up after reading the broadcast,
    // 21 answer with a corrupt frame. The round must complete on its
    // deadline with every failure counted and attributed, and the *next*
    // round must still work with the healthy remainder.
    let n = 64usize;
    let healthy = 22usize; // ids 0..22
    let leavers = 21usize; // ids 22..43
    let d = 128usize;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        for id in 0..n {
            let addr = addr.clone();
            let spec = &spec;
            scope.spawn(move || {
                let mut t = TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap();
                loop {
                    match t.recv() {
                        Ok(Some(wire::Message::Round { round, .. })) => {
                            if id >= healthy && id < healthy + leavers {
                                return; // storm: vanish mid-round
                            }
                            let g = vec![(id + 1) as f32; d];
                            let (payload, _, report) =
                                encode_once(&NoCompression, &g, spec).unwrap();
                            let up = Uplink {
                                client_id: id,
                                round,
                                payload,
                                report,
                                train_loss: 0.0,
                                error: None,
                            };
                            let mut f = wire::encode_update(&up);
                            if id >= healthy + leavers {
                                let at = f.len() / 2;
                                f[at] ^= 0x01; // storm: corrupt frame
                            }
                            if t.send(&f).is_err() {
                                return; // server closed us (expected)
                            }
                        }
                        _ => return, // shutdown or server-side close
                    }
                }
            });
        }

        let mut transport = TcpServerTransport::accept(&listener, n, NET_TIMEOUT).unwrap();
        let cfg = ServerConfig { straggler_timeout_ms: 800, ..Default::default() };
        let mut server = FedServer::new(cfg, n, 1, Box::new(NoCompression));
        let participants: Vec<usize> = (0..n).collect();
        let mut w = vec![0.0f32; d];
        let s = server.run_round(0, &participants, &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s.received, healthy);
        assert_eq!(s.decode_errors, n - healthy - leavers);
        assert_eq!(s.dropped, n - healthy);
        // per-client attribution: every corrupt sender has exactly one
        // decode error, nobody else has any
        for id in 0..n {
            let expect = usize::from(id >= healthy + leavers);
            assert_eq!(server.sessions[id].decode_errors, expect, "client {id}");
        }
        let ts = transport.stats();
        assert_eq!(ts.decode_errors, (n - healthy - leavers) as u64);
        assert!(
            ts.disconnects >= leavers as u64,
            "only {} disconnects observed for {leavers} leavers",
            ts.disconnects
        );
        // the next round degrades to the healthy remainder — no abort
        let s1 = server.run_round(1, &participants, &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s1.received, healthy);
        assert_eq!(s1.dropped, n - healthy);
        assert_eq!(s1.decode_errors, 0);
        transport.close().unwrap();
    });
}

#[test]
fn reactor_runs_128_clients_through_the_sim_path() {
    let mut cfg = ExperimentConfig::new("sim", Scheme::TopKUniform, 2, 2);
    cfg.n_clients = 128;
    cfg.server.shards = 4;
    cfg.server.straggler_timeout_ms = 60_000;
    let rep = simulate_with(&cfg, 512, TransportMode::TcpLoopback).unwrap();
    assert_eq!(rep.stats.rounds.len(), 2);
    assert_eq!(rep.stats.total_received(), 256);
    assert_eq!(rep.stats.total_dropped(), 0);
    assert_eq!(rep.stats.transport.per_client.len(), 128);
    assert!(rep.stats.transport.per_client.iter().all(|&(i, o)| i > 0 && o > 0));
    assert_eq!(rep.stats.transport.decode_errors, 0);
    assert_eq!(rep.stats.transport.disconnects, 0);
    assert!(rep.w_norm() > 0.0);
}
