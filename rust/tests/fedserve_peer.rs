//! Integration: cross-process PS peering (`fedserve::peer`).
//!
//! The acceptance oracle for the PR: a range cluster whose non-lead
//! members live in *other processes* (here: follower threads running the
//! same `serve_peer` body the `repro serve --peer` process runs, over real
//! TCP loopback sockets) must be **bit-exact** against the identically
//! shaped in-process `PsCluster` for every registered scheme — the
//! follower runs the same fused reduce over the same survivor payloads in
//! the same f32 fold order, so shipping the sub-step over the wire must
//! not move a single bit. On top of that:
//!
//! * replica mode holds the same parity through its eq.-(7) sync barrier;
//! * a follower killed mid-run (the `die_after_rounds` chaos hook) misses
//!   the sync barrier, is dropped from the membership and attributed in
//!   `ClusterStats::peer_drops`, the lead reduces the dropped member's
//!   sub-step locally (the identical code path — the final model stays
//!   bit-exact), and the survivors keep serving every remaining round.

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use m22::compress::{encode_once, registry, SchemeSpec};
use m22::config::{ClusterConfig, ExperimentConfig, PsMode, Scheme, ServerConfig};
use m22::coordinator::Uplink;
use m22::fedserve::sim::sim_spec;
use m22::fedserve::transport::{TcpClientTransport, TcpServerTransport, Transport};
use m22::fedserve::wire::{self, PeerMembership};
use m22::fedserve::{serve_peer, LruTableCache, PeerSet, PsCluster, RoundAssembler};
use m22::metrics::ClusterStats;
use m22::quantizer::Family;

const NET_TIMEOUT: Duration = Duration::from_secs(30);
const N_CLIENTS: usize = 4;
const K: usize = 3;
const D: usize = 256;

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

fn all_schemes() -> Vec<Scheme> {
    vec![
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ]
}

/// A deterministic per-(client, round) gradient: both the peered and the
/// in-process run feed the cluster byte-identical uplinks.
fn grad(id: usize, round: usize, d: usize) -> Vec<f32> {
    (0..d)
        .map(|j| {
            let x = (id.wrapping_mul(7919))
                .wrapping_add(round.wrapping_mul(104_729))
                .wrapping_add(j.wrapping_mul(31))
                % 997;
            x as f32 / 498.5 - 1.0
        })
        .collect()
}

/// A well-behaved sim client: assemble each round broadcast (full frame or
/// model-parallel slices), answer with the scheme-encoded gradient, leave
/// on shutdown.
fn client_loop(addr: &str, id: usize, sspec: SchemeSpec) {
    let spec = sim_spec(D);
    let enc = registry::build_encoder(
        &sspec,
        Arc::new(m22::compress::CpuCodec::new()),
        Arc::new(LruTableCache::new(64)),
    )
    .unwrap();
    let mut t = TcpClientTransport::connect(addr, id, NET_TIMEOUT).unwrap();
    let mut asm = RoundAssembler::new();
    loop {
        let msg = match t.recv() {
            Ok(Some(m)) => m,
            _ => return, // server-side close
        };
        if !matches!(msg, wire::Message::Round { .. } | wire::Message::RoundSlice { .. }) {
            return; // shutdown
        }
        if asm.feed(msg).unwrap() {
            let round = asm.round();
            let g = grad(id, round, D);
            let (payload, _, report) = encode_once(enc.as_ref(), &g, &spec).unwrap();
            let up = Uplink { client_id: id, round, payload, report, train_loss: 0.0, error: None };
            if t.send(&wire::encode_update(&up)).is_err() {
                return;
            }
        }
    }
}

/// Drive one cluster run over real sockets. `remote` > 0 promotes members
/// `1..=remote` to follower threads running [`serve_peer`] — the same body
/// a `repro serve --peer ADDR` process runs; `remote` = 0 is the fully
/// in-process reference. `die_after` kills the FIRST follower after that
/// many served sub-steps (chaos).
fn run_cluster(
    scheme: Scheme,
    mode: PsMode,
    n_ps: usize,
    remote: usize,
    die_after: Option<usize>,
    barrier_timeout_ms: u64,
    rounds: usize,
) -> (Vec<f32>, ClusterStats) {
    let cfg = ExperimentConfig::new("sim", scheme, 2, rounds);
    let sspec = cfg.scheme_spec(D);
    let spec = sim_spec(D);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let peer_listener = (remote > 0).then(|| TcpListener::bind("127.0.0.1:0").unwrap());
    let peer_addr = peer_listener.as_ref().map(|l| l.local_addr().unwrap().to_string());
    std::thread::scope(|scope| {
        for i in 0..remote {
            let pa = peer_addr.clone().unwrap();
            let die = if i == 0 { die_after } else { None };
            scope.spawn(move || {
                // a chaos follower vanishes mid-run by design: no unwrap
                let _ = serve_peer(&pa, NET_TIMEOUT, die, 64);
            });
        }
        for id in 0..N_CLIENTS {
            let addr = addr.clone();
            scope.spawn(move || client_loop(&addr, id, sspec));
        }

        let mut transport = TcpServerTransport::accept(&listener, N_CLIENTS, NET_TIMEOUT).unwrap();
        let scfg = ServerConfig::builder()
            .shards(2)
            .straggler_timeout_ms(30_000)
            .prewarm(false)
            .build();
        let ccfg = ClusterConfig::builder()
            .n_ps(n_ps)
            .mode(mode)
            .sync_every(1)
            .peers(remote)
            .barrier_timeout_ms(barrier_timeout_ms)
            .build();
        let decoders: Vec<_> = (0..n_ps)
            .map(|_| {
                registry::build_decoder(
                    &sspec,
                    Arc::new(m22::compress::CpuCodec::new()),
                    Arc::new(LruTableCache::new(64)),
                )
                .unwrap()
            })
            .collect();
        let mut cluster =
            PsCluster::new(&ccfg, &scfg, N_CLIENTS, D, cfg.seed, decoders).unwrap();
        if let Some(pl) = &peer_listener {
            // the same grant the serve arm's RunPlan builds from the config
            let template = PeerMembership {
                member: 0,
                n_ps,
                mode,
                sync_every: ccfg.sync_every,
                d: D,
                shards: scfg.shards,
                spec: sspec,
            };
            let set =
                PeerSet::accept(pl, remote, NET_TIMEOUT, barrier_timeout_ms, &template).unwrap();
            cluster.attach_peers(set).unwrap();
        }
        let mut w = vec![0.0f32; D];
        for r in 0..rounds {
            cluster.run_round(r, K, &mut transport, &spec, &mut w).unwrap();
        }
        cluster.finish(&mut w);
        let stats = cluster.cluster_stats();
        transport.close().unwrap();
        (w, stats)
    })
}

/// ISSUE 9 acceptance: a range cluster whose second member reduces in a
/// follower process is bit-exact against the in-process cluster for every
/// registered scheme — the sub-step wire trip moves ownership, never
/// arithmetic.
#[test]
fn peered_range_cluster_is_bit_exact_for_every_scheme() {
    for scheme in all_schemes() {
        let (w_ref, cs_ref) = run_cluster(scheme, PsMode::Range, 2, 0, None, 0, 2);
        assert!(w_ref.iter().any(|&x| x != 0.0), "{scheme:?}: reference did nothing");
        assert_eq!(cs_ref.peers, 0, "{scheme:?}");
        let (w, cs) = run_cluster(scheme, PsMode::Range, 2, 1, None, 0, 2);
        assert_bitwise_eq(&w_ref, &w, &format!("{scheme:?} peered range"));
        assert_eq!(cs.peers, 1, "{scheme:?}");
        assert_eq!(cs.peer_drops, 0, "{scheme:?}: a healthy follower was dropped");
        assert!(cs.summary().contains("1 remote peer(s)"), "{scheme:?}: {}", cs.summary());
    }
}

/// Two remote members behind one lead (a 3-member cluster with only the
/// lead in-process) hold the same range-mode parity over more rounds.
#[test]
fn two_remote_peers_match_the_in_process_cluster() {
    let scheme = Scheme::M22 { family: Family::GenNorm, m: 2.0 };
    let (w_ref, _) = run_cluster(scheme, PsMode::Range, 3, 0, None, 0, 3);
    let (w, cs) = run_cluster(scheme, PsMode::Range, 3, 2, None, 0, 3);
    assert_bitwise_eq(&w_ref, &w, "2 remote peers");
    assert_eq!(cs.peers, 2);
    assert_eq!(cs.peer_drops, 0);
}

/// Replica mode ships full-width replicas and span payloads instead of
/// slices; the eq.-(7) sync barrier folds the remote replica exactly like
/// the in-process one.
#[test]
fn peered_replica_cluster_matches_the_in_process_sync() {
    let scheme = Scheme::TopKUniform;
    let (w_ref, _) = run_cluster(scheme, PsMode::Replica, 2, 0, None, 0, 2);
    let (w, cs) = run_cluster(scheme, PsMode::Replica, 2, 1, None, 0, 2);
    assert_bitwise_eq(&w_ref, &w, "peered replica");
    assert_eq!(cs.peers, 1);
    assert_eq!(cs.peer_drops, 0);
}

/// The kill-a-peer chaos test: the follower serves one sub-step and
/// vanishes without a goodbye. The lead's next barrier must drop it (not
/// hang), run the member's reduce locally — bit-exact against the fully
/// in-process run — attribute the drop in `ClusterStats`, and keep the
/// survivors serving every remaining round.
#[test]
fn killed_peer_is_dropped_attributed_and_survivors_finish_bit_exact() {
    let scheme = Scheme::TopKUniform;
    let rounds = 3;
    let (w_ref, _) = run_cluster(scheme, PsMode::Range, 2, 0, None, 0, rounds);
    let (w, cs) = run_cluster(scheme, PsMode::Range, 2, 1, Some(1), 5_000, rounds);
    assert_bitwise_eq(&w_ref, &w, "kill-a-peer fallback");
    assert_eq!(cs.peers, 1);
    assert_eq!(cs.peer_drops, 1, "the dead follower was never attributed");
    let sum = cs.summary();
    assert!(sum.contains("1 peer(s) dropped at the barrier"), "{sum}");
    // the survivors (the lead and its local members) recorded every round
    for ps in &cs.per_ps {
        assert_eq!(ps.rounds.len(), rounds, "a survivor stopped serving: {sum}");
    }
}
