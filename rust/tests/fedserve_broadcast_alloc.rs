//! Byte accounting for round broadcasts (C100K regression).
//!
//! Queueing one broadcast to k clients used to copy the encoded frame k
//! times; now every outbound queue holds the same `Arc<[u8]>`. This test
//! pins that with a counting global allocator: fanning a multi-megabyte
//! frame out to 256 clients must allocate a small fraction of ONE frame
//! (queue nodes), nowhere near 256 frames.
//!
//! The counting allocator is process-global, so this file holds exactly
//! one test — no parallel neighbors polluting the measurement window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use m22::fedserve::transport::{ChannelTransport, Transport};
use m22::fedserve::wire;

/// Counts bytes *requested* (allocations and realloc growth); frees are
/// deliberately not subtracted — the test bounds allocation traffic, not
/// the high-water mark.
struct CountingAlloc;

static BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        BYTES.fetch_add((new_size as u64).saturating_sub(layout.size() as u64), Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn broadcast_allocations_do_not_scale_with_fleet_size() {
    let k = 256usize;
    let d = 1usize << 20; // 4 MiB of weights
    let (mut transport, clients) = ChannelTransport::pair(k);
    let w = vec![1.0f32; d];
    let frame: Arc<[u8]> = wire::encode_round(7, &w).into();
    let frame_len = frame.len() as u64;
    assert!(frame_len > 4_000_000);

    let before = BYTES.load(Ordering::Relaxed);
    for c in 0..k {
        transport.send(c, &frame).unwrap();
    }
    let fanout = BYTES.load(Ordering::Relaxed) - before;

    // the old copy-per-client path cost k × frame_len ≈ 1 GiB here; the
    // Arc fan-out costs queue nodes only — well under one frame's worth
    assert!(
        fanout < frame_len / 8,
        "broadcast to {k} clients allocated {fanout} bytes (one frame is {frame_len})"
    );
    // and every queue really holds the same bytes: one Arc per queued
    // downlink plus the caller's handle
    assert_eq!(Arc::strong_count(&frame), k + 1);
    drop(clients);
}
