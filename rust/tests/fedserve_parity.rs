//! Integration: the full fedserve path (client sessions → wire frames →
//! fused sparse decode+reduce on shards) reproduces a hand-rolled serial
//! dense-decode coordinator bit-exactly at every shard count, and the
//! shared LRU quantizer-table cache actually gets hit in multi-round runs.
//!
//! This is the acceptance oracle for the Encoder/Decoder split: the serial
//! reference below decodes every payload *densely* (the pre-split server
//! behavior) while `simulate` runs the fused `accumulate_sharded` path that
//! never materializes a per-client ĝ — final models must agree to the bit.

use std::sync::Arc;

use m22::compress::{encode_once, BlockCodec, CpuCodec, Decoder};
use m22::config::{ExperimentConfig, Scheme};
use m22::coordinator::Memory;
use m22::fedserve::aggregate::{
    accumulate_serial, accumulate_sharded, aggregate_serial, aggregate_sharded,
};
use m22::fedserve::session::Scheduler;
use m22::fedserve::sim::{sim_spec, sim_update, simulate};
use m22::fedserve::table_cache::LruTableCache;
use m22::quantizer::Family;
use m22::util::rng::Rng;

fn base_cfg(scheme: Scheme, clients: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new("sim", scheme, 2, rounds);
    cfg.n_clients = clients;
    cfg
}

/// The serial reference: same schedule, same sessions, same decoders — but
/// no wire, no threads, no sharding, and *dense* decode-then-reduce (the
/// old `Compressor::decompress` server path). This is the pre-fedserve,
/// pre-split driver loop.
fn serial_reference(cfg: &ExperimentConfig, d: usize) -> Vec<f32> {
    let spec = sim_spec(d);
    let tables = Arc::new(LruTableCache::new(cfg.server.table_cache_capacity));
    let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::new());
    let decoder = cfg.build_decoder(d, codec.clone(), tables.clone()).unwrap();
    let comps: Vec<_> = (0..cfg.n_clients)
        .map(|_| cfg.build_encoder(d, codec.clone(), tables.clone()).unwrap())
        .collect();
    let mut mems: Vec<Option<Memory>> = (0..cfg.n_clients)
        .map(|_| cfg.memory.then(|| Memory::new(d, cfg.memory_decay)))
        .collect();
    let mut sched = Scheduler::new(cfg.seed);
    let k = cfg.participants_per_round();
    let mut w = vec![0.0f32; d];
    for round in 0..cfg.rounds {
        let participants = sched.sample(cfg.n_clients, k);
        let mut decoded = Vec::with_capacity(participants.len());
        for &id in &participants {
            let update = sim_update(cfg.seed, id, round, d);
            let augmented = match &mems[id] {
                Some(m) => m.add_back(&update).unwrap(),
                None => update.clone(),
            };
            let (payload, reconstructed, _) =
                encode_once(&*comps[id], &augmented, &spec).unwrap();
            if let Some(m) = &mut mems[id] {
                m.update(&augmented, &reconstructed);
            }
            // the server decodes bytes, never the client's reconstruction
            decoded.push(decoder.decode_dense(&payload, &spec).unwrap());
        }
        let agg = aggregate_serial(&decoded, d);
        let scale = 1.0 / participants.len() as f32;
        for (wi, a) in w.iter_mut().zip(&agg) {
            *wi -= scale * a;
        }
    }
    w
}

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

#[test]
fn sharded_aggregation_parity_across_shard_counts() {
    // pure dense aggregation parity on synthetic decoded deltas
    let root = Rng::new(4242);
    for &(n, d) in &[(2usize, 999usize), (6, 4096), (11, 10_000)] {
        let decoded: Vec<Vec<f32>> = (0..n)
            .map(|c| {
                let mut r = root.stream(3, c as u64);
                (0..d).map(|_| (r.normal() * 0.2) as f32).collect()
            })
            .collect();
        let serial = aggregate_serial(&decoded, d);
        for shards in [1usize, 3, 8] {
            let sharded = aggregate_sharded(&decoded, d, shards);
            assert_bitwise_eq(&serial, &sharded, &format!("n={n} d={d} shards={shards}"));
        }
    }
}

#[test]
fn fused_sparse_reduce_matches_dense_reduce_for_every_scheme() {
    // decode_accumulate / for_each_survivor vs decode_dense + dense reduce:
    // bit-exact at every shard count, for every scheme's real payloads
    let d = 3000;
    let spec = sim_spec(d);
    let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::new());
    for scheme in [
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ] {
        let cfg = base_cfg(scheme, 5, 1);
        let tables = Arc::new(LruTableCache::new(64));
        let encoder = cfg.build_encoder(d, codec.clone(), tables.clone()).unwrap();
        let decoder = cfg.build_decoder(d, codec.clone(), tables.clone()).unwrap();
        let payloads: Vec<Vec<u8>> = (0..cfg.n_clients)
            .map(|id| {
                let g = sim_update(cfg.seed, id, 0, d);
                encode_once(&*encoder, &g, &spec).unwrap().0
            })
            .collect();
        let slices: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let decoded: Vec<Vec<f32>> = slices
            .iter()
            .map(|p| decoder.decode_dense(p, &spec).unwrap())
            .collect();
        let dense = aggregate_serial(&decoded, d);
        let mut acc = vec![0.0f32; d];
        accumulate_serial(&*decoder, &slices, &spec, &mut acc).unwrap();
        assert_bitwise_eq(&dense, &acc, &format!("{scheme:?} serial"));
        for shards in [3usize, 8] {
            let mut acc = vec![0.0f32; d];
            accumulate_sharded(&*decoder, &slices, &spec, shards, &mut acc).unwrap();
            assert_bitwise_eq(&dense, &acc, &format!("{scheme:?} shards={shards}"));
        }
    }
}

#[test]
fn wire_driver_reproduces_serial_coordinator_m22() {
    let d = 4096;
    let cfg = base_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 5, 4);
    let reference = serial_reference(&cfg, d);
    assert!(reference.iter().any(|&x| x != 0.0), "reference did nothing");
    for shards in [1usize, 3, 8] {
        let mut c = cfg.clone();
        c.server.shards = shards;
        let rep = simulate(&c, d).unwrap();
        assert_bitwise_eq(&reference, &rep.w, &format!("shards={shards}"));
        // acceptance: the shared table cache shows hits in a multi-round run
        assert!(
            rep.stats.cache_hits > 0,
            "shards={shards}: no cache hits ({:?})",
            rep.stats
        );
        assert_eq!(rep.stats.rounds.len(), 4);
        assert_eq!(rep.stats.total_dropped(), 0);
        assert!(rep.stats.total_framed_bytes() > 0);
    }
}

#[test]
fn wire_driver_parity_with_memory_and_partial_participation() {
    let d = 2000;
    let mut cfg = base_cfg(Scheme::M22 { family: Family::Weibull, m: 4.0 }, 8, 5);
    cfg.memory = true;
    cfg.memory_decay = 0.5;
    cfg.server.sampled_clients = Some(3);
    let reference = serial_reference(&cfg, d);
    for shards in [1usize, 8] {
        let mut c = cfg.clone();
        c.server.shards = shards;
        let rep = simulate(&c, d).unwrap();
        assert_bitwise_eq(&reference, &rep.w, &format!("memory shards={shards}"));
        for t in &rep.stats.rounds {
            assert_eq!(t.received, 3);
        }
    }
}

#[test]
fn wire_driver_parity_other_schemes() {
    // schemes without table lookups must also survive the wire + shards
    let d = 1024;
    for scheme in [Scheme::TopKUniform, Scheme::TopKFp { bits: 8 }, Scheme::None] {
        let cfg = base_cfg(scheme, 4, 3);
        let reference = serial_reference(&cfg, d);
        let mut c = cfg.clone();
        c.server.shards = 3;
        let rep = simulate(&c, d).unwrap();
        assert_bitwise_eq(&reference, &rep.w, &format!("{scheme:?}"));
    }
}
