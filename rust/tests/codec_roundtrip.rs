//! Property tests for the split Encoder/Decoder API, run in the default
//! `cargo test` lane (CI):
//!
//! 1. **Fused-accumulate equivalence** — for every scheme and random
//!    weight, `decode_accumulate(p, w, acc)` is bit-exactly
//!    `acc[i] += w · decode_dense(p)[i]` (zero entries included: skipping
//!    a zero survivor is an exact f32 no-op for accumulators that never
//!    hold −0.0, which aggregation accumulators — zero-initialized and
//!    add-only — cannot).
//! 2. **Encode determinism under scratch reuse** — an [`EncodeCtx`] dirtied
//!    by encoding other gradients produces byte- and bit-identical output
//!    to a fresh one; stale buffer contents must never leak into a round.

use std::sync::Arc;

use m22::compress::registry::{self, Scheme, SchemeSpec};
use m22::compress::{BlockCodec, Budget, CpuCodec, Decoder, EncodeCtx, Encoder};
use m22::fedserve::sim::sim_spec;
use m22::quantizer::{Family, QuantizerTables, TableSource};
use m22::util::prop::prop_check;

fn all_schemes() -> Vec<Scheme> {
    vec![
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ]
}

fn build_pair(scheme: Scheme, b: &Budget, seed: u64) -> (Box<dyn Encoder>, Box<dyn Decoder>) {
    let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::new());
    let tables: Arc<dyn TableSource> = Arc::new(QuantizerTables::new());
    let spec = SchemeSpec::new(scheme, 0, 0).resolve(b, seed);
    let enc = registry::build_encoder(&spec, codec.clone(), tables.clone()).unwrap();
    let dec = registry::build_decoder(&spec, codec, tables).unwrap();
    (enc, dec)
}

/// Drop the (astronomically unlikely) −0.0 a generator could produce: the
/// equivalence below is stated for accumulators without negative zeros,
/// which is the only kind the add-only aggregation path can hold.
fn sanitize(acc: Vec<f32>) -> Vec<f32> {
    acc.into_iter().map(|x| if x == 0.0 { 0.0 } else { x }).collect()
}

#[test]
fn decode_accumulate_equals_weighted_dense_decode_bitwise() {
    prop_check("decode_accumulate ≡ acc += w·dense", 12, |g| {
        let d = g.usize_in(400, 2000);
        let spec = sim_spec(d);
        let b = Budget::paper_point(d, *g.pick(&[1u32, 2, 3, 4]));
        let grad = g.grad_like(d..d + 1, g.f64_in(0.0, 0.6));
        let weight = *g.pick(&[1.0f32, -1.0, 0.5, 2.25, 0.0]);
        for scheme in all_schemes() {
            let (enc, dec) = build_pair(scheme, &b, 7);
            let mut ctx = EncodeCtx::new();
            enc.encode(&grad, &spec, &mut ctx).unwrap();
            let dense = dec.decode_dense(ctx.payload(), &spec).unwrap();
            assert_eq!(dense.len(), d, "{scheme:?}");
            // dense reference: acc2[i] += w * dense[i] over every dimension
            let acc0 = sanitize(g.vec_f32(d..d + 1, -1.0, 1.0));
            let mut want = acc0.clone();
            for (a, x) in want.iter_mut().zip(&dense) {
                *a += weight * x;
            }
            let mut acc = acc0;
            dec.decode_accumulate(ctx.payload(), &spec, weight, &mut acc).unwrap();
            for i in 0..d {
                assert_eq!(
                    acc[i].to_bits(),
                    want[i].to_bits(),
                    "{scheme:?} w={weight} dim {i}: {} vs {}",
                    acc[i],
                    want[i]
                );
            }
        }
    });
}

#[test]
fn encode_is_deterministic_under_ctx_reuse() {
    prop_check("dirty scratch never leaks", 10, |g| {
        let d = g.usize_in(400, 1500);
        let spec = sim_spec(d);
        let b = Budget::paper_point(d, *g.pick(&[1u32, 2, 3]));
        let grad = g.grad_like(d..d + 1, g.f64_in(0.0, 0.5));
        // a different gradient (possibly different support size) to dirty
        // every scratch buffer first
        let other = g.grad_like(d..d + 1, g.f64_in(0.0, 0.9));
        for scheme in all_schemes() {
            let (enc, _) = build_pair(scheme, &b, 7);
            let mut fresh = EncodeCtx::new();
            let r1 = enc.encode(&grad, &spec, &mut fresh).unwrap();
            let clean_payload = fresh.payload().to_vec();
            let clean_ghat = fresh.reconstructed().to_vec();

            let mut dirty = EncodeCtx::new();
            enc.encode(&other, &spec, &mut dirty).unwrap();
            let r2 = enc.encode(&grad, &spec, &mut dirty).unwrap();
            assert_eq!(dirty.payload(), &clean_payload[..], "{scheme:?}: payload drifted");
            let got = dirty.reconstructed();
            assert_eq!(got.len(), clean_ghat.len(), "{scheme:?}");
            for i in 0..got.len() {
                assert_eq!(
                    got[i].to_bits(),
                    clean_ghat[i].to_bits(),
                    "{scheme:?}: reconstruction drifted at dim {i}"
                );
            }
            assert_eq!(r1.payload_bytes, r2.payload_bytes, "{scheme:?}");
            assert_eq!(r1.k, r2.k, "{scheme:?}");
        }
    });
}

#[test]
fn zero_weight_and_zero_acc_edge_cases() {
    let d = 600;
    let spec = sim_spec(d);
    let b = Budget::paper_point(d, 2);
    for scheme in all_schemes() {
        let (enc, dec) = build_pair(scheme, &b, 3);
        let grad: Vec<f32> = (0..d).map(|i| ((i % 7) as f32 - 3.0) * 0.01).collect();
        let mut ctx = EncodeCtx::new();
        enc.encode(&grad, &spec, &mut ctx).unwrap();
        // zero-initialized accumulator at weight 1 reproduces dense decode
        let mut acc = vec![0.0f32; d];
        dec.decode_accumulate(ctx.payload(), &spec, 1.0, &mut acc).unwrap();
        let dense = dec.decode_dense(ctx.payload(), &spec).unwrap();
        for i in 0..d {
            assert_eq!(acc[i].to_bits(), dense[i].to_bits(), "{scheme:?} dim {i}");
        }
        // wrong-dimension accumulator is rejected, not corrupted
        let mut short = vec![0.0f32; d - 1];
        assert!(dec.decode_accumulate(ctx.payload(), &spec, 1.0, &mut short).is_err());
    }
}
