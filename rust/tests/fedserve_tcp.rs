//! Integration: fedserve over real loopback sockets.
//!
//! The bandwidth-constrained channel is the paper's whole premise, so the
//! framed-bit accounting has to survive a genuine network boundary:
//! * channel-vs-TCP **bit parity** for every registry scheme (the transport
//!   moves bytes, it never touches numerics) — the same oracle style as
//!   `tests/fedserve_parity.rs`, with the channel run as the reference;
//! * k-of-n selection with a deliberately stalled client hitting the
//!   straggler deadline over a real socket;
//! * clean shutdown with no leaked threads (every test runs under
//!   `std::thread::scope`, which cannot return while a thread lives);
//! * fault injection at the wire/transport boundary: frames split at
//!   arbitrary byte offsets, dribbled one byte at a time, and corrupted —
//!   reassembly resumes across splits, corruption is a typed error.

use std::net::TcpListener;
use std::time::Duration;

use m22::compress::{encode_once, NoCompression};
use m22::config::{ExperimentConfig, Scheme, ServerConfig};
use m22::coordinator::Uplink;
use m22::fedserve::sim::{sim_spec, simulate_with, TransportMode};
use m22::fedserve::transport::{
    ClientTransport, Event, FrameBuffer, TcpClientTransport, TcpServerTransport, Transport,
};
use m22::fedserve::wire::{self, FrameError};
use m22::fedserve::FedServer;
use m22::quantizer::Family;

const NET_TIMEOUT: Duration = Duration::from_secs(30);

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

fn base_cfg(scheme: Scheme, clients: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new("sim", scheme, 2, rounds);
    cfg.n_clients = clients;
    // generous deadline: irrelevant when every client answers, but keeps a
    // wedged run from hanging CI instead of failing
    cfg.server.straggler_timeout_ms = 30_000;
    cfg
}

#[test]
fn tcp_loopback_bit_parity_with_channel_for_every_scheme() {
    let d = 1500;
    for scheme in [
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ] {
        let mut cfg = base_cfg(scheme, 4, 3);
        cfg.server.shards = 3;
        let chan = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
        let tcp = simulate_with(&cfg, d, TransportMode::TcpLoopback).unwrap();
        assert_bitwise_eq(&chan.w, &tcp.w, &format!("{scheme:?}"));
        assert!(chan.w.iter().any(|&x| x != 0.0), "{scheme:?}: run did nothing");
        // framed accounting is now measured at the socket
        assert_eq!(tcp.stats.transport.label, "tcp");
        assert_eq!(chan.stats.transport.label, "channel");
        assert!(
            tcp.stats.transport.bytes_in >= tcp.stats.total_framed_bytes(),
            "{scheme:?}: socket counted {} B in < {} framed B",
            tcp.stats.transport.bytes_in,
            tcp.stats.total_framed_bytes()
        );
        assert_eq!(tcp.stats.transport.decode_errors, 0, "{scheme:?}");
        assert_eq!(tcp.stats.total_dropped(), 0, "{scheme:?}");
    }
}

#[test]
fn tcp_loopback_parity_with_memory_and_partial_participation() {
    let d = 1024;
    let mut cfg = base_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 6, 4);
    cfg.memory = true;
    cfg.memory_decay = 0.5;
    cfg.server.sampled_clients = Some(3);
    cfg.server.shards = 8;
    let chan = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
    let tcp = simulate_with(&cfg, d, TransportMode::TcpLoopback).unwrap();
    assert_bitwise_eq(&chan.w, &tcp.w, "memory + k-of-n");
    for t in &tcp.stats.rounds {
        assert_eq!(t.received, 3);
        assert_eq!(t.dropped, 0);
    }
}

#[test]
fn tcp_straggler_hits_the_deadline_and_the_round_survives() {
    let d = 256;
    let spec = sim_spec(d);
    let n = 4;
    let rounds = 2;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        // k-of-n selection: clients 0..=2 are sampled every round (client 3
        // stays connected but unsampled). Clients 0 and 1 answer; client 2
        // reads its downlinks but never uplinks — the deliberate straggler.
        for id in 0..n {
            let addr = addr.clone();
            let spec = &spec;
            scope.spawn(move || {
                let mut t = TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap();
                loop {
                    match t.recv() {
                        Ok(Some(wire::Message::Round { round, .. })) => {
                            if id == 2 {
                                continue; // stall: read rounds, answer none
                            }
                            let g = vec![(id + 1) as f32; d];
                            let (payload, _, report) =
                                encode_once(&NoCompression, &g, spec).unwrap();
                            let up = Uplink {
                                client_id: id,
                                round,
                                payload,
                                report,
                                train_loss: 0.0,
                                error: None,
                            };
                            t.send(&wire::encode_update(&up)).unwrap();
                        }
                        // shutdown frame or server-close: either releases us
                        _ => return,
                    }
                }
            });
        }

        let mut transport = TcpServerTransport::accept(&listener, n, NET_TIMEOUT).unwrap();
        let cfg = ServerConfig { straggler_timeout_ms: 400, ..Default::default() };
        let mut server = FedServer::new(cfg, n, 1, Box::new(NoCompression));
        let mut w = vec![0.0f32; d];
        for round in 0..rounds {
            let s = server.run_round(round, &[0, 1, 2], &mut transport, &spec, &mut w).unwrap();
            assert_eq!(s.received, 2, "round {round}");
            assert_eq!(s.dropped, 1, "round {round}");
            assert_eq!(s.decode_errors, 0, "round {round}");
        }
        assert_eq!(server.sessions[2].dropped, rounds);
        assert_eq!(server.sessions[2].participated, 0);
        assert_eq!(server.sessions[0].participated, rounds);
        assert_eq!(server.sessions[1].participated, rounds);
        // the unsampled client was never selected, never dropped
        assert_eq!(server.sessions[3].participated, 0);
        assert_eq!(server.sessions[3].dropped, 0);
        // graceful shutdown releases the straggler too; the scope below
        // joins every client thread — a leak would hang, not pass
        transport.close().unwrap();
    });
}

#[test]
fn tcp_malformed_uplink_is_counted_per_client_and_round_completes() {
    let d = 128;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        for id in 0..2 {
            let addr = addr.clone();
            let spec = &spec;
            scope.spawn(move || {
                let mut t = TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap();
                let mut first = true;
                loop {
                    match t.recv() {
                        Ok(Some(wire::Message::Round { round, .. })) => {
                            let g = vec![(id + 1) as f32; d];
                            let (payload, _, report) =
                                encode_once(&NoCompression, &g, spec).unwrap();
                            let up = Uplink {
                                client_id: id,
                                round,
                                payload,
                                report,
                                train_loss: 0.0,
                                error: None,
                            };
                            let mut f = wire::encode_update(&up);
                            if id == 0 && first {
                                // a corrupt uplink: valid prefix, one
                                // flipped byte mid-frame
                                let n = f.len();
                                f[n / 2] ^= 0x01;
                            }
                            first = false;
                            if t.send(&f).is_err() {
                                return; // the server dropped this connection
                            }
                        }
                        // shutdown frame, or the server closed our socket
                        _ => return,
                    }
                }
            });
        }

        let mut transport = TcpServerTransport::accept(&listener, 2, NET_TIMEOUT).unwrap();
        let cfg = ServerConfig { straggler_timeout_ms: 10_000, ..Default::default() };
        let mut server = FedServer::new(cfg, 2, 1, Box::new(NoCompression));
        let mut w = vec![0.0f32; d];
        let s = server.run_round(0, &[0, 1], &mut transport, &spec, &mut w).unwrap();
        // the corrupt sender is attributed, counted, and not waited for —
        // the round completes on client 1 alone, well before the deadline
        assert_eq!(s.decode_errors, 1);
        assert_eq!(s.received, 1);
        assert_eq!(s.dropped, 1);
        assert_eq!(server.sessions[0].decode_errors, 1);
        assert_eq!(server.sessions[1].decode_errors, 0);
        assert_eq!(w, vec![-2.0f32; d]); // only client 1's update landed
        assert_eq!(transport.stats().decode_errors, 1);
        // the corrupt client's connection is gone, but the run survives:
        // the next round counts its failed downlink as a drop and carries
        // on with the healthy client
        let s1 = server.run_round(1, &[0, 1], &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s1.received, 1);
        assert_eq!(s1.dropped, 1);
        assert_eq!(s1.decode_errors, 0);
        assert_eq!(w, vec![-4.0f32; d]);
        assert_eq!(server.sessions[0].dropped, 2);
        transport.close().unwrap();
    });
}

#[test]
fn tcp_shutdown_is_clean_across_back_to_back_runs() {
    // two consecutive loopback runs: the first one's threads, sockets, and
    // port must be fully released for the second to pass (simulate_with
    // joins its client threads via thread::scope before returning)
    let mut cfg = base_cfg(Scheme::TopKUniform, 4, 2);
    cfg.server.shards = 2;
    let a = simulate_with(&cfg, 512, TransportMode::TcpLoopback).unwrap();
    let b = simulate_with(&cfg, 512, TransportMode::TcpLoopback).unwrap();
    assert_bitwise_eq(&a.w, &b.w, "repeat run");
    assert_eq!(a.stats.transport.bytes_in, b.stats.transport.bytes_in);
}

// ---------------------------------------------------------------------
// fault injection at the wire/transport boundary
// ---------------------------------------------------------------------

#[test]
fn reassembly_resumes_across_every_split_point() {
    let f1 = wire::encode_round(7, &[1.0f32, -2.5, f32::NAN, 0.0]);
    let f2 = wire::encode_update(&Uplink {
        client_id: 3,
        round: 7,
        payload: vec![9u8; 37],
        report: Default::default(),
        train_loss: 0.25,
        error: None,
    });
    let mut stream = f1.clone();
    stream.extend_from_slice(&f2);
    for cut in 0..=stream.len() {
        let mut fb = FrameBuffer::new();
        let mut got = Vec::new();
        fb.extend(&stream[..cut]);
        while let Some((m, _)) = fb.next_frame().unwrap() {
            got.push(m);
        }
        fb.extend(&stream[cut..]);
        while let Some((m, _)) = fb.next_frame().unwrap() {
            got.push(m);
        }
        assert_eq!(got.len(), 2, "cut at {cut}");
        assert!(matches!(got[0], wire::Message::Round { round: 7, .. }), "cut at {cut}");
        match &got[1] {
            wire::Message::Update(u) => assert_eq!(u.payload, vec![9u8; 37], "cut at {cut}"),
            other => panic!("cut at {cut}: wrong second frame {other:?}"),
        }
        assert_eq!(fb.pending(), 0, "cut at {cut}");
    }
}

#[test]
fn reassembly_survives_duplicated_partial_reads() {
    // a transport that delivers one byte per read, polling after every
    // push: incomplete polls must consume nothing and stay repeatable
    let f = wire::encode_round(3, &[0.25f32; 64]);
    let mut fb = FrameBuffer::new();
    for &b in &f[..f.len() - 1] {
        fb.extend(&[b]);
        assert!(fb.next_frame().unwrap().is_none());
        assert!(fb.next_frame().unwrap().is_none()); // double-poll: idempotent
    }
    fb.extend(&f[f.len() - 1..]);
    let (msg, used) = fb.next_frame().unwrap().unwrap();
    assert_eq!(used, f.len());
    assert!(matches!(msg, wire::Message::Round { round: 3, .. }));
}

#[test]
fn one_flipped_payload_byte_is_a_typed_crc_error() {
    let f = wire::encode_round(1, &[4.0f32; 16]);
    for at in wire::HEADER_BYTES..f.len() {
        let mut bad = f.clone();
        bad[at] ^= 0x10;
        let mut fb = FrameBuffer::new();
        fb.extend(&bad);
        match fb.next_frame() {
            Err(FrameError::BadCrc { got, want }) => assert_ne!(got, want, "byte {at}"),
            other => panic!("byte {at}: expected BadCrc, got {other:?}"),
        }
    }
    // header damage is typed too, and caught before the frame completes
    let mut bad = f.clone();
    bad[0] ^= 0xff;
    let mut fb = FrameBuffer::new();
    fb.extend(&bad[..1]);
    assert!(matches!(fb.next_frame(), Err(FrameError::BadMagic { .. })));
    let mut bad = f;
    bad[2] = 200;
    let mut fb = FrameBuffer::new();
    fb.extend(&bad[..3]);
    assert!(matches!(fb.next_frame(), Err(FrameError::BadVersion { got: 200 })));
}

#[test]
fn transport_shim_split_duplicate_and_flip_against_a_live_server() {
    // end-to-end shim: a raw TCP client that (a) splits its handshake and
    // uplink frames at awkward offsets with pauses between fragments, and
    // (b) then sends a flipped-byte frame — the server reassembles (a)
    // and surfaces (b) as a counted Garbage event
    use std::io::Write;
    use std::net::TcpStream;

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        scope.spawn(|| {
            let mut s = TcpStream::connect(&addr).unwrap();
            s.set_nodelay(true).unwrap();
            let hello = wire::encode_hello(0);
            // dribble the handshake: 1 byte, pause, the rest
            s.write_all(&hello[..1]).unwrap();
            std::thread::sleep(Duration::from_millis(20));
            s.write_all(&hello[1..]).unwrap();
            // a valid frame split into three fragments with pauses
            let good = wire::encode_hello(777);
            for chunk in [&good[..3], &good[3..7], &good[7..]] {
                s.write_all(chunk).unwrap();
                std::thread::sleep(Duration::from_millis(15));
            }
            // then a flipped byte inside a second frame
            let mut bad = wire::encode_hello(888);
            bad[9] ^= 0x40;
            s.write_all(&bad).unwrap();
            // hold the socket open until the server has seen everything
            std::thread::sleep(Duration::from_millis(200));
        });

        let mut transport = TcpServerTransport::accept(&listener, 1, NET_TIMEOUT).unwrap();
        match transport.poll(Some(NET_TIMEOUT)).unwrap().unwrap() {
            Event::Frame { msg: wire::Message::Hello { client: 777 }, .. } => {}
            other => panic!("expected the split frame first, got {other:?}"),
        }
        match transport.poll(Some(NET_TIMEOUT)).unwrap().unwrap() {
            Event::Garbage { client: Some(0), error, .. } => {
                assert!(error.contains("checksum"), "{error}");
            }
            other => panic!("expected garbage second, got {other:?}"),
        }
        assert_eq!(transport.stats().decode_errors, 1);
    });
}

#[test]
fn loopback_client_connect_requires_a_listening_server_eventually() {
    // connect() retries, so a client may race ahead of the listener — but
    // a server that never appears is a clean error, not a hang
    let patience = Duration::from_millis(120);
    let err = TcpClientTransport::connect("127.0.0.1:1", 0, patience).unwrap_err();
    assert!(format!("{err:#}").contains("connecting to 127.0.0.1:1"), "{err:#}");
}
