//! Integration: PJRT runtime loads the real AOT artifacts and executes them.
//!
//! These tests need `make artifacts`; they are skipped (not failed) when the
//! artifacts directory is absent so `cargo test` works on a fresh checkout.

use std::path::PathBuf;
use std::sync::Arc;

use m22::compress::{BlockCodec, CpuCodec};
use m22::data::{Dataset, DatasetConfig};
use m22::quantizer::{design, Family, QuantizerTables};
use m22::stats::{Distribution, GenNorm};
use m22::util::rng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! skip_without_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn handle() -> m22::runtime::RuntimeHandle {
    // one shared service for the whole test binary
    use std::sync::OnceLock;
    static HANDLE: OnceLock<m22::runtime::RuntimeHandle> = OnceLock::new();
    HANDLE
        .get_or_init(|| m22::runtime::spawn(artifacts_dir().unwrap()).expect("runtime spawn"))
        .clone()
}

#[test]
fn smoke_artifact_reproduces_reference() {
    skip_without_artifacts!();
    // same numbers as /opt/xla-example/load_hlo: matmul+2 => [5,5,9,9]
    assert_eq!(handle().smoke().unwrap(), vec![5.0, 5.0, 9.0, 9.0]);
}

#[test]
fn hlo_quantize_matches_cpu_codec() {
    skip_without_artifacts!();
    let h = handle();
    let mut rng = Rng::new(5);
    // arbitrary length exercises chunk+pad
    let g: Vec<f32> = (0..100_000)
        .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() as f32 })
        .collect();
    let q = design(&GenNorm::standardized(1.2), 2.0, 8);
    let (t, c) = q.padded_f32(16);
    let (ih, gh) = h.quantize(&g, &t, &c).unwrap();
    let (ic, gc) = CpuCodec::new().quantize(&g, &t, &c).unwrap();
    assert_eq!(ih, ic);
    assert_eq!(gh, gc);
}

#[test]
fn hlo_moments_match_cpu_codec() {
    skip_without_artifacts!();
    let h = handle();
    let mut rng = Rng::new(7);
    let g: Vec<f32> = (0..70_000).map(|_| (rng.normal() * 0.02) as f32).collect();
    let mh = h.moments(&g).unwrap();
    let mc = CpuCodec::new().moments(&g).unwrap();
    for i in 0..8 {
        let rel = (mh[i] - mc[i]).abs() / mc[i].abs().max(1.0);
        // kernel accumulates in f32; CPU reference in f64
        assert!(rel < 2e-4, "stat {i}: {} vs {}", mh[i], mc[i]);
    }
}

#[test]
fn hlo_distortion_matches_reference() {
    skip_without_artifacts!();
    let h = handle();
    let mut rng = Rng::new(9);
    let g: Vec<f32> = (0..80_000).map(|_| rng.normal() as f32).collect();
    let ghat: Vec<f32> = g.iter().map(|x| x + 0.1).collect();
    for m in [0.0f32, 2.0] {
        let d = h.distortion(&g, &ghat, m).unwrap();
        let expect: f64 = g
            .iter()
            .map(|&x| (x as f64).abs().powf(m as f64) * 0.1f64.powi(2))
            .sum();
        let rel = (d as f64 - expect).abs() / expect;
        assert!(rel < 5e-3, "m={m}: {d} vs {expect}");
    }
}

#[test]
fn train_step_and_eval_consistent() {
    skip_without_artifacts!();
    let h = handle();
    let ds = Dataset::generate(DatasetConfig { train_per_class: 16, test_per_class: 4, ..Default::default() });
    let man = m22::train::Manifest::load(&artifacts_dir().unwrap()).unwrap();
    for arch in ["cnn_s", "resnet_s", "vgg_s"] {
        let w = man.load_init(&artifacts_dir().unwrap(), arch).unwrap();
        let b = ds.batch(&ds.train, 0, man.batch);
        let step = h.train_step(arch, &w, &b.x, &b.y).unwrap();
        assert!(step.loss.is_finite() && step.loss > 0.0, "{arch} loss {}", step.loss);
        assert!((0.0..=1.0).contains(&step.acc));
        assert_eq!(step.grads.len(), w.len());
        let gnorm: f64 = step.grads.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
        assert!(gnorm > 0.0 && gnorm.is_finite(), "{arch} gnorm {gnorm}");
        // eval on the same batch reports the same metrics
        let (el, ea) = h.eval(arch, &w, &b.x, &b.y).unwrap();
        assert!((el - step.loss).abs() < 1e-4, "{arch}: {el} vs {}", step.loss);
        assert!((ea - step.acc).abs() < 1e-6);
    }
}

#[test]
fn sgd_through_artifacts_learns() {
    skip_without_artifacts!();
    let h = handle();
    let dir = artifacts_dir().unwrap();
    let man = m22::train::Manifest::load(&dir).unwrap();
    let ds = Dataset::generate(DatasetConfig { train_per_class: 32, test_per_class: 4, ..Default::default() });
    let arch = "cnn_s";
    let mut w = man.load_init(&dir, arch).unwrap();
    let b = ds.batch(&ds.train, 0, man.batch);
    let first = h.train_step(arch, &w, &b.x, &b.y).unwrap();
    let mut loss = first.loss;
    let mut grads = first.grads;
    for _ in 0..15 {
        for (wi, gi) in w.iter_mut().zip(&grads) {
            *wi -= 0.05 * gi;
        }
        let s = h.train_step(arch, &w, &b.x, &b.y).unwrap();
        loss = s.loss;
        grads = s.grads;
    }
    assert!(loss < first.loss * 0.9, "no learning: {} -> {loss}", first.loss);
}

#[test]
fn m22_compressor_on_hlo_codec_roundtrips() {
    skip_without_artifacts!();
    let h = handle();
    let dir = artifacts_dir().unwrap();
    let man = m22::train::Manifest::load(&dir).unwrap();
    let spec = man.model("cnn_s").unwrap();
    let mut rng = Rng::new(11);
    let g: Vec<f32> = (0..spec.d()).map(|_| (rng.normal() * 0.01) as f32).collect();
    let tables = Arc::new(QuantizerTables::new());
    let k = (0.6 * spec.d() as f64) as usize;
    use m22::compress::m22::{M22, M22Config};
    use m22::compress::{encode_once, Decoder};
    let comp = M22::new(
        M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k, min_fit: 512 },
        Arc::new(h.clone()),
        tables.clone(),
    );
    let (payload, reconstructed, report) = encode_once(&comp, &g, spec).unwrap();
    assert_eq!(report.k, k);
    let dec = comp.decode_dense(&payload, spec).unwrap();
    assert_eq!(dec, reconstructed);
    // and the HLO path agrees with the pure-Rust codec end to end
    let comp_cpu = M22::new(
        M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k, min_fit: 512 },
        Arc::new(CpuCodec::new()),
        tables,
    );
    let (_, reconstructed_cpu, _) = encode_once(&comp_cpu, &g, spec).unwrap();
    // HLO moments accumulate in f32, the CPU reference in f64, so fitted
    // scales differ in the last ulp: compare reconstructions approximately
    // and supports exactly.
    assert_eq!(reconstructed.len(), reconstructed_cpu.len());
    let mut max_rel = 0.0f64;
    for (a, b) in reconstructed.iter().zip(&reconstructed_cpu) {
        assert_eq!(*a == 0.0, *b == 0.0, "support mismatch");
        if *b != 0.0 {
            max_rel = max_rel.max(((a - b) as f64 / *b as f64).abs());
        }
    }
    assert!(max_rel < 1e-3, "HLO vs CPU codec rel diff {max_rel}");
}
