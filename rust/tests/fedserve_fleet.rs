//! Integration: the discrete-event fleet simulator drives the REAL
//! `FedServer`/`PsCluster` through the virtual-time `FleetTransport`.
//!
//! The acceptance oracle is the channel simulation: with zero latency
//! jitter, no churn, and IID data, a fleet run must be **bit-exact**
//! against `simulate_with(.., TransportMode::Channel)` for every
//! registered scheme at the same seed — same k-of-n sample, same wire
//! frames, same fused reduce. On top of that, heterogeneous scenarios
//! (lognormal stragglers dropped at a virtual deadline, join/leave churn
//! over 50k modeled clients, a sharded PS cluster) must complete and
//! replay bit-exactly, because every draw is a pure function of
//! `(seed, client)` and the straggler deadline lives on the virtual clock.

use m22::config::{all_schemes, ClusterConfig, ExperimentConfig, PsMode, Scheme, ScenarioSpec};
use m22::fedserve::{simulate_fleet, simulate_with, FleetReport, TransportMode};

fn fleet_cfg(scheme: Scheme, n: usize, k: usize, rounds: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new("sim", scheme, 2, rounds);
    cfg.n_clients = n;
    cfg.server.sampled_clients = Some(k);
    cfg
}

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

fn run(cfg: &ExperimentConfig, scn: &ScenarioSpec, d: usize) -> FleetReport {
    simulate_fleet(cfg, scn, d).expect("fleet run")
}

/// Satellite 4 (ISSUE acceptance): a fleet scenario with zero latency
/// jitter, no churn, and IID data is bit-exact against the channel sim
/// for every registered scheme — same seed, same k-of-n sample.
#[test]
fn zero_jitter_iid_fleet_is_bit_exact_vs_channel_for_every_scheme() {
    let d = 1024;
    let scn = ScenarioSpec::parse("fleet:n=12,churn=0,lat=fixed,jitter=0").unwrap();
    for scheme in all_schemes() {
        let cfg = fleet_cfg(scheme, 12, 5, 3);
        let fleet = run(&cfg, &scn, d);
        let channel = simulate_with(&cfg, d, TransportMode::Channel).expect("channel sim");
        let label = cfg.scheme.label(cfg.rq);
        assert_bitwise_eq(&fleet.sim.w, &channel.w, &label);
        assert_eq!(fleet.sim.stats.transport.label, "fleet", "{label}");
        assert_eq!(fleet.sim.stats.rounds.len(), 3, "{label}");
        assert_eq!(fleet.scenario.scheme, label);
    }
}

/// The parity also holds with client-side error-feedback memory: fleet
/// sessions persist across rounds exactly like channel client threads do.
#[test]
fn fleet_parity_holds_with_error_feedback_memory() {
    let d = 1024;
    let scn = ScenarioSpec::parse("fleet:n=10,churn=0,lat=fixed,jitter=0").unwrap();
    let mut cfg = fleet_cfg(Scheme::parse("m22-gennorm", 2.0).unwrap(), 10, 4, 4);
    cfg.memory = true;
    cfg.memory_decay = 0.5;
    let fleet = run(&cfg, &scn, d);
    let channel = simulate_with(&cfg, d, TransportMode::Channel).expect("channel sim");
    assert_bitwise_eq(&fleet.sim.w, &channel.w, "memory parity");
}

/// Heavy-tailed stragglers against a virtual deadline: drops happen, are
/// attributed per round, and the whole run replays bit-exactly — the
/// deadline is mapped onto the virtual clock, so no host timing leaks in.
#[test]
fn virtual_deadline_drops_stragglers_deterministically() {
    let d = 512;
    let scn = ScenarioSpec::parse("fleet:n=400,lat=lognorm,jitter=1.5,lat_ms=80").unwrap();
    let mut cfg = fleet_cfg(Scheme::TopKUniform, 400, 32, 3);
    cfg.server.straggler_timeout_ms = 160;
    let a = run(&cfg, &scn, d);
    let b = run(&cfg, &scn, d);
    assert_bitwise_eq(&a.sim.w, &b.sim.w, "straggler replay");
    assert_eq!(a.scenario.received, b.scenario.received);
    assert_eq!(a.scenario.dropped, b.scenario.dropped);
    let mut dropped = 0;
    for t in &a.sim.stats.rounds {
        assert_eq!(t.received + t.dropped, 32, "round {}: accounting", t.round);
        assert!(t.received > 0, "round {}: everyone dropped", t.round);
        dropped += t.dropped;
    }
    assert!(dropped > 0, "jitter=1.5 around an 80 ms median never missed a 160 ms deadline");
    assert_eq!(a.scenario.received + a.scenario.dropped, 3 * 32);
}

/// 50k modeled clients with churn and Dirichlet skew: completes without
/// materializing the population, skips departed clients, replays exactly.
#[test]
fn churn_scenarios_complete_and_replay_bit_exactly() {
    let d = 256;
    let scn =
        ScenarioSpec::parse("fleet:n=50000,alpha=0.1,churn=0.05,lat=lognorm,jitter=0.5").unwrap();
    let cfg = fleet_cfg(Scheme::TopKUniform, 50_000, 64, 3);
    let a = run(&cfg, &scn, d);
    let b = run(&cfg, &scn, d);
    assert_bitwise_eq(&a.sim.w, &b.sim.w, "churn replay");
    for t in &a.sim.stats.rounds {
        // no deadline configured: every live sampled participant reports
        assert_eq!(t.received, 64, "round {}", t.round);
        assert_eq!(t.dropped, 0, "round {}", t.round);
    }
    assert!(a.sim.stats.transport.wakeups > 0);
    // α = 0.1 over 10 classes is strongly skewed: max-class share well
    // above the 0.1 IID level
    assert!(a.scenario.label_skew > 0.15, "skew = {}", a.scenario.label_skew);
    assert!(a.scenario.per_bit.is_finite());
    assert!(a.scenario.scenario.contains("alpha=0.1"));
}

/// ISSUE 7 acceptance: on a bandwidth-starved heterogeneous-link fleet,
/// the closed-loop controller (`--adaptive`) achieves strictly higher
/// per-bit accuracy than EVERY fixed scheme in the registry — it re-fits
/// the residual, re-selects (family, m, rq), and lowers each client's K
/// to its drawn link's capacity, while fixed schemes burn the full
/// keep-frac budget over links that cannot amortize it. Seed-pinned: the
/// whole loop replays bit-exactly.
#[test]
fn adaptive_beats_every_fixed_scheme_per_bit_on_starved_links() {
    let d = 2048;
    let scn =
        ScenarioSpec::parse("fleet:n=64,churn=0,lat=lognorm,jitter=0.4,lat_ms=50,bw=0.002")
            .unwrap();
    let mut acfg = fleet_cfg(Scheme::TopKUniform, 64, 16, 4);
    acfg.server.adaptive = true;
    let adaptive = run(&acfg, &scn, d);
    let apb = adaptive.scenario.per_bit;
    assert!(apb.is_finite() && apb > 0.0, "adaptive per-bit = {apb}");
    // the controller actually moved through the scheme space (round 0
    // serves the base, later rounds the re-designed M22 points)...
    assert!(
        adaptive.scenario.schemes >= 2,
        "trajectory never left the base: {:?}",
        adaptive.scenario
    );
    assert!(adaptive.sim.stats.rounds[1..]
        .iter()
        .all(|t| t.ad_family == "G" || t.ad_family == "W"));
    // ...and the (family, m, rq, spread) trajectory lands in the CSV
    let csv = adaptive.to_csv();
    assert!(csv.lines().any(|l| l.contains(",G,") || l.contains(",W,")), "{csv}");
    // every fixed scheme spends more bits per unit of final metric
    for scheme in all_schemes() {
        let cfg = fleet_cfg(scheme, 64, 16, 4);
        let fixed = run(&cfg, &scn, d);
        let label = cfg.scheme.label(cfg.rq);
        assert_eq!(fixed.scenario.schemes, 1, "{label}: fixed run left its scheme");
        let fpb = fixed.scenario.per_bit;
        assert!(fpb.is_finite(), "{label}: per-bit = {fpb}");
        assert!(apb > fpb, "{label}: adaptive {apb:.3e} <= fixed {fpb:.3e}");
    }
    // seed-pinned determinism across the full adaptive loop
    let again = run(&acfg, &scn, d);
    assert_bitwise_eq(&adaptive.sim.w, &again.sim.w, "adaptive replay");
    assert_eq!(adaptive.scenario.per_bit.to_bits(), again.scenario.per_bit.to_bits());
    assert_eq!(adaptive.scenario.schemes, again.scenario.schemes);
}

/// Satellite 2: `--table-cache` on the fleet arm — a second fleet run
/// reloads the tables the first one designed and persisted, serving its
/// lookups as cross-run prewarm hits without changing any numbers.
#[test]
fn fleet_table_cache_persists_across_runs_with_prewarm_hits() {
    let mut path = std::env::temp_dir();
    path.push(format!("m22-fleet-tables-{}", std::process::id()));
    std::fs::remove_file(&path).ok();
    let d = 1024;
    let scn = ScenarioSpec::parse("fleet:n=12,churn=0,lat=fixed,jitter=0").unwrap();
    let mut cfg = fleet_cfg(Scheme::parse("m22-gennorm", 2.0).unwrap(), 12, 5, 2);
    cfg.server.table_cache_path = Some(path.to_string_lossy().into_owned());
    let cold = run(&cfg, &scn, d);
    assert!(path.exists(), "no cache file persisted");
    assert_eq!(cold.sim.stats.preloaded_tables, 0);
    let warm = run(&cfg, &scn, d);
    // the second run reloaded what the first one designed...
    assert!(warm.sim.stats.preloaded_tables > 0, "{:?}", warm.sim.stats);
    // ...every table lookup resolves against a preloaded/prewarmed entry
    // (cross-run prewarm-hit attribution), with some hits guaranteed by
    // the repeated per-round fits
    assert!(warm.sim.stats.cache_hits > 0, "{:?}", warm.sim.stats);
    assert_eq!(
        warm.sim.stats.prewarm_hits, warm.sim.stats.cache_hits,
        "a fully-preloaded run should serve every hit from a prewarmed table: {:?}",
        warm.sim.stats
    );
    // ...and persistence is a cache warmup, never a numerics change
    assert_bitwise_eq(&cold.sim.w, &warm.sim.w, "cache reload");
    std::fs::remove_file(&path).ok();
}

/// The fleet feeds a sharded PS cluster through the same virtual
/// transport: range mode stays bit-exact vs the single-server fleet, and
/// churn is refused (per-PS schedulers sample internally).
#[test]
fn cluster_fleet_runs_with_per_ps_rollup() {
    let d = 512;
    let scn = ScenarioSpec::parse("fleet:n=40,churn=0,lat=fixed,jitter=0").unwrap();
    let single = fleet_cfg(Scheme::TopKUniform, 40, 8, 3);
    let mut clustered = single.clone();
    clustered.server.cluster =
        Some(ClusterConfig::builder().n_ps(2).mode(PsMode::Range).build());
    let a = run(&single, &scn, d);
    let b = run(&clustered, &scn, d);
    let rollup = b.sim.cluster.as_ref().expect("cluster rollup");
    assert_eq!(rollup.n_ps(), 2);
    // range sharding is model-parallel over dimension slices: bit-exact
    assert_bitwise_eq(&a.sim.w, &b.sim.w, "range cluster vs single PS");
    // churn + cluster is a config error, not a silent wrong answer
    let churny = ScenarioSpec::parse("fleet:n=40,churn=0.1,lat=fixed,jitter=0").unwrap();
    let e = simulate_fleet(&clustered, &churny, d).unwrap_err();
    assert!(format!("{e:#}").contains("churn is not supported"), "{e:#}");
}
