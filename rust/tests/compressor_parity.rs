//! Integration: cross-scheme rate parity and qualitative quality ordering
//! on realistic (model-shaped) gradients — no runtime needed.

use std::sync::Arc;

use m22::compress::m22::{M22, M22Config};
use m22::compress::uniform::TopKUniform;
use m22::compress::{encode_once, Budget, CpuCodec, Decoder};
use m22::quantizer::{Family, QuantizerTables};
use m22::stats::{Distribution, GenNorm};
use m22::train::{ModelSpec, TensorInfo, TensorKind};
use m22::util::rng::Rng;

/// A CNN-shaped layout: two conv tensors + dense + biases.
fn model_spec() -> ModelSpec {
    let tensors = vec![
        ("conv1.w", 432, TensorKind::Conv),
        ("conv1.b", 24, TensorKind::Bias),
        ("conv2.w", 10368, TensorKind::Conv),
        ("conv2.b", 48, TensorKind::Bias),
        ("fc.w", 41472, TensorKind::Dense),
        ("fc.b", 96, TensorKind::Bias),
    ];
    let mut offset = 0;
    let tensors: Vec<TensorInfo> = tensors
        .into_iter()
        .map(|(name, size, kind)| {
            let t = TensorInfo { name: name.into(), shape: vec![size], kind, offset, size };
            offset += size;
            t
        })
        .collect();
    ModelSpec {
        arch: "cnn_shaped".into(),
        total_params: offset,
        conv_params: 10800,
        dense_params: 41472,
        bias_params: 168,
        tensors,
    }
}

/// Long-tailed per-layer gradients (GenNorm beta < 1, per-layer scales) —
/// the regime the paper's Fig. 1 documents.
fn realistic_grad(spec: &ModelSpec, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut g = vec![0.0f32; spec.d()];
    for (ti, t) in spec.tensors.iter().enumerate() {
        let scale = 10f64.powf(-2.0 - 0.5 * (ti % 3) as f64);
        let dist = GenNorm::new(scale, 0.8);
        for i in t.offset..t.offset + t.size {
            g[i] = dist.sample(&mut rng) as f32;
        }
    }
    g
}

fn mse(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>() / a.len() as f64
}

/// Weighted distortion the paper's quantizer optimizes (eq. 12 squared form).
fn weighted_distortion(g: &[f32], ghat: &[f32], m: f64) -> f64 {
    g.iter()
        .zip(ghat)
        .map(|(&x, &y)| {
            let a = (x as f64).abs();
            let w = if a > 0.0 { a.powf(m) } else if m == 0.0 { 1.0 } else { 0.0 };
            w * ((x - y) as f64).powi(2)
        })
        .sum::<f64>()
}

#[test]
fn value_bits_match_across_quantizer_schemes() {
    let spec = model_spec();
    let g = realistic_grad(&spec, 1);
    let b = Budget::paper_point(spec.d(), 2);
    let tables = Arc::new(QuantizerTables::new());
    let codec = Arc::new(CpuCodec::new());
    let uniform = TopKUniform::new(2, b.k_ref);
    let m22 = M22::new(
        M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k: b.k_ref, min_fit: 512 },
        codec,
        tables,
    );
    let (_, _, ru) = encode_once(&uniform, &g, &spec).unwrap();
    let (_, _, rm) = encode_once(&m22, &g, &spec).unwrap();
    // eq. 15 vs eq. 17: identical K and identical value budget
    assert_eq!(ru.k, rm.k);
    assert_eq!(ru.value_bits, rm.value_bits);
    // positional terms identical too (same K over same d)
    assert_eq!(ru.position_bits_actual, rm.position_bits_actual);
}

#[test]
fn m22_beats_uniform_on_long_tailed_gradients() {
    // The paper's core claim, in codec form: at matched budget the
    // LBG/GenNorm quantizer reconstructs long-tailed gradients with lower
    // MSE than the uniform quantizer.
    let spec = model_spec();
    let tables = Arc::new(QuantizerTables::new());
    for rq in [1u32, 2, 3] {
        let b = Budget::paper_point(spec.d(), rq);
        let mut err_u = 0.0;
        let mut err_m = 0.0;
        for seed in 0..3u64 {
            let g = realistic_grad(&spec, seed);
            let (_, rec_u, _) =
                encode_once(&TopKUniform::new(rq, b.k_ref), &g, &spec).unwrap();
            let m22 = M22::new(
                M22Config { family: Family::GenNorm, m: 0.0, rq, k: b.k_ref, min_fit: 512 },
                Arc::new(CpuCodec::new()),
                tables.clone(),
            );
            let (_, rec_m, _) = encode_once(&m22, &g, &spec).unwrap();
            err_u += mse(&g, &rec_u);
            err_m += mse(&g, &rec_m);
        }
        assert!(err_m < err_u, "rq={rq}: m22 {err_m} vs uniform {err_u}");
    }
}

#[test]
fn matched_m_minimizes_its_own_distortion() {
    // The quantizer designed for weight exponent M should win *under that
    // M-weighted metric* against designs for other M (sanity of eq. 13).
    let spec = model_spec();
    let tables = Arc::new(QuantizerTables::new());
    let b = Budget::paper_point(spec.d(), 3);
    let g = realistic_grad(&spec, 9);
    let compress_with = |m: f64| {
        let c = M22::new(
            M22Config { family: Family::GenNorm, m, rq: 3, k: b.k_ref, min_fit: 512 },
            Arc::new(CpuCodec::new()),
            tables.clone(),
        );
        encode_once(&c, &g, &spec).unwrap().1
    };
    let r0 = compress_with(0.0);
    let r4 = compress_with(4.0);
    // under the M=4 metric, the M=4 design wins; under M=0 (plain MSE), M=0 wins
    assert!(weighted_distortion(&g, &r4, 4.0) < weighted_distortion(&g, &r0, 4.0));
    assert!(weighted_distortion(&g, &r0, 0.0) < weighted_distortion(&g, &r4, 0.0));
}

#[test]
fn per_layer_fit_beats_global_fit() {
    // Per-layer scales differ by orders of magnitude; fitting per tensor
    // (min_fit small) must beat one global quantizer (min_fit huge).
    let spec = model_spec();
    let tables = Arc::new(QuantizerTables::new());
    let b = Budget::paper_point(spec.d(), 2);
    let g = realistic_grad(&spec, 17);
    let rec = |min_fit: usize| {
        let c = M22::new(
            M22Config { family: Family::GenNorm, m: 0.0, rq: 2, k: b.k_ref, min_fit },
            Arc::new(CpuCodec::new()),
            tables.clone(),
        );
        encode_once(&c, &g, &spec).unwrap().1
    };
    let per_layer = mse(&g, &rec(256));
    let global = mse(&g, &rec(usize::MAX));
    assert!(per_layer < global, "per-layer {per_layer} vs global {global}");
}

#[test]
fn weibull_family_also_roundtrips_on_realistic_grads() {
    let spec = model_spec();
    let g = realistic_grad(&spec, 23);
    let b = Budget::paper_point(spec.d(), 1);
    let c = M22::new(
        M22Config { family: Family::Weibull, m: 4.0, rq: 1, k: b.k_ref, min_fit: 512 },
        Arc::new(CpuCodec::new()),
        Arc::new(QuantizerTables::new()),
    );
    let (payload, reconstructed, _) = encode_once(&c, &g, &spec).unwrap();
    assert_eq!(c.decode_dense(&payload, &spec).unwrap(), reconstructed);
    // 1-bit quantization: reconstruction correlates positively with source
    let dot: f64 = g.iter().zip(&reconstructed).map(|(a, b)| (a * b) as f64).sum();
    assert!(dot > 0.0);
}
