//! Integration: the fedserve wire protocol round-trips arbitrary payloads
//! bit-exactly and rejects every corruption we can throw at it.

use m22::compress::{RateReport, Scheme, SchemeSpec};
use m22::config::PsMode;
use m22::coordinator::Uplink;
use m22::fedserve::wire::{
    self, decode, decode_prefix, encode_round, encode_shutdown, encode_update, FrameError,
    FrameKind, PeerMembership,
};
use m22::quantizer::Family;
use m22::util::prop::prop_check;

#[test]
fn round_frames_roundtrip_property() {
    prop_check("wire round roundtrip", 60, |g| {
        let round = g.usize_in(0, 1_000_000);
        let mut weights = g.vec_f32(0..2000, -1e6, 1e6);
        // sprinkle special values — the frame must carry raw bits
        if !weights.is_empty() {
            weights[0] = f32::NAN;
            let n = weights.len();
            weights[n - 1] = -0.0;
        }
        let frame = encode_round(round, &weights);
        match decode(&frame).unwrap() {
            wire::Message::Round { round: r, weights: w } => {
                assert_eq!(r, round);
                assert_eq!(w.len(), weights.len());
                for (a, b) in w.iter().zip(&weights) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong message {other:?}"),
        }
    });
}

fn arbitrary_uplink(g: &mut m22::util::prop::Gen) -> Uplink {
    let n = g.usize_in(0, 4096);
    let payload: Vec<u8> = (0..n).map(|_| (g.rng.next_u64() & 0xff) as u8).collect();
    let error = if g.bool() {
        None
    } else {
        Some(format!("client exploded at step {}", g.usize_in(0, 100)))
    };
    Uplink {
        client_id: g.usize_in(0, 10_000),
        round: g.usize_in(0, 10_000),
        payload,
        report: RateReport {
            d: g.usize_in(1, 1_000_000),
            k: g.usize_in(0, 500_000),
            position_bits_ideal: g.f64_in(0.0, 1e9),
            position_bits_actual: g.usize_in(0, 1_000_000) as u64,
            value_bits: g.usize_in(0, 1_000_000) as u64,
            side_bits: g.usize_in(0, 10_000) as u64,
            payload_bytes: g.usize_in(0, 4096),
        },
        train_loss: g.f64_in(-10.0, 10.0),
        error,
    }
}

#[test]
fn update_frames_roundtrip_property() {
    prop_check("wire update roundtrip", 60, |g| {
        let up = arbitrary_uplink(g);
        let frame = encode_update(&up);
        match decode(&frame).unwrap() {
            wire::Message::Update(u) => {
                assert_eq!(u.client_id, up.client_id);
                assert_eq!(u.round, up.round);
                assert_eq!(u.payload, up.payload);
                assert_eq!(u.train_loss.to_bits(), up.train_loss.to_bits());
                assert_eq!(u.error, up.error);
                assert_eq!(u.report.d, up.report.d);
                assert_eq!(u.report.k, up.report.k);
                assert_eq!(
                    u.report.position_bits_ideal.to_bits(),
                    up.report.position_bits_ideal.to_bits()
                );
                assert_eq!(u.report.position_bits_actual, up.report.position_bits_actual);
                assert_eq!(u.report.value_bits, up.report.value_bits);
                assert_eq!(u.report.side_bits, up.report.side_bits);
                assert_eq!(u.report.payload_bytes, up.report.payload_bytes);
            }
            other => panic!("wrong message {other:?}"),
        }
    });
}

#[test]
fn corrupted_frames_rejected_property() {
    prop_check("wire corruption rejected", 80, |g| {
        let frame = if g.bool() {
            encode_update(&arbitrary_uplink(g))
        } else {
            encode_round(g.usize_in(0, 100), &g.vec_f32(1..256, -2.0, 2.0))
        };
        let mut bad = frame.clone();
        let at = g.usize_in(0, bad.len());
        let flip = 1 + (g.rng.next_u64() % 255) as u8;
        bad[at] ^= flip;
        assert!(decode(&bad).is_err(), "byte {at} xor {flip:#x} accepted");
    });
}

#[test]
fn truncation_rejected_property() {
    prop_check("wire truncation rejected", 40, |g| {
        let frame = encode_round(g.usize_in(0, 100), &g.vec_f32(1..512, -2.0, 2.0));
        let cut = g.usize_in(0, frame.len());
        assert!(decode(&frame[..cut]).is_err(), "truncation to {cut} accepted");
    });
}

#[test]
fn streaming_reader_walks_mixed_frames() {
    let mut buf = Vec::new();
    let frames = vec![
        encode_round(0, &[1.0, 2.0]),
        encode_update(&Uplink {
            client_id: 1,
            round: 0,
            payload: vec![9, 9, 9],
            report: RateReport::default(),
            train_loss: 0.5,
            error: None,
        }),
        encode_shutdown(),
    ];
    for f in &frames {
        buf.extend_from_slice(f);
    }
    let mut off = 0;
    let mut seen = Vec::new();
    while off < buf.len() {
        let (msg, used) = decode_prefix(&buf[off..]).unwrap();
        off += used;
        seen.push(msg);
    }
    assert_eq!(off, buf.len());
    assert_eq!(seen.len(), 3);
    assert!(matches!(seen[0], wire::Message::Round { .. }));
    assert!(matches!(seen[1], wire::Message::Update(_)));
    assert!(matches!(seen[2], wire::Message::Shutdown));
}

fn arbitrary_spec(g: &mut m22::util::prop::Gen) -> SchemeSpec {
    let scheme = match g.usize_in(0, 6) {
        0 => Scheme::M22 {
            family: if g.bool() { Family::GenNorm } else { Family::Weibull },
            m: g.f64_in(0.5, 8.0),
        },
        1 => Scheme::TinyScript,
        2 => Scheme::TopKUniform,
        3 => Scheme::TopKFp { bits: if g.bool() { 4 } else { 8 } },
        4 => Scheme::CountSketch,
        _ => Scheme::None,
    };
    SchemeSpec {
        scheme,
        rq: g.usize_in(1, 17) as u32,
        k: g.usize_in(0, 1 << 20),
        min_fit: g.usize_in(0, 4096),
        sketch_depth: g.usize_in(1, 17),
        seed: g.rng.next_u64(),
    }
}

fn arbitrary_payloads(g: &mut m22::util::prop::Gen) -> Vec<Vec<u8>> {
    let np = g.usize_in(0, 6);
    (0..np)
        .map(|_| {
            let n = g.usize_in(0, 512);
            (0..n).map(|_| (g.rng.next_u64() & 0xff) as u8).collect()
        })
        .collect()
}

/// A weight vector carrying raw-bit landmines (NaN, -0.0) so a roundtrip
/// that survives proves bit-exact transport, not value-equal transport.
fn arbitrary_weights(g: &mut m22::util::prop::Gen, len: usize) -> Vec<f32> {
    let mut w = g.vec_f32(len..len + 1, -1e6, 1e6);
    if !w.is_empty() {
        w[0] = f32::NAN;
        let n = w.len();
        w[n - 1] = -0.0;
    }
    w
}

fn assert_bits_eq(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// ISSUE 9 satellite: every peer frame (hello, membership grant, range
/// sub-step, slice reply, replica sub-step, replica sync) round-trips
/// arbitrary contents bit-exactly.
#[test]
fn peer_frames_roundtrip_property() {
    prop_check("wire peer roundtrip", 60, |g| {
        let member = g.usize_in(0, 10_000);
        match decode(&wire::encode_peer_hello(member)).unwrap() {
            wire::Message::PeerHello { member: m } => assert_eq!(m, member),
            other => panic!("wrong message {other:?}"),
        }

        let m = PeerMembership {
            member: g.usize_in(1, 64),
            n_ps: g.usize_in(1, 64),
            mode: if g.bool() { PsMode::Range } else { PsMode::Replica },
            sync_every: g.usize_in(0, 100),
            d: g.usize_in(1, 1 << 20),
            shards: g.usize_in(1, 64),
            spec: arbitrary_spec(g),
        };
        match decode(&wire::encode_peer_membership(&m)).unwrap() {
            wire::Message::PeerMembership(got) => {
                assert_eq!(got.member, m.member);
                assert_eq!(got.n_ps, m.n_ps);
                assert_eq!(got.mode, m.mode);
                assert_eq!(got.sync_every, m.sync_every);
                assert_eq!(got.d, m.d);
                assert_eq!(got.shards, m.shards);
                assert_eq!(got.spec, m.spec);
            }
            other => panic!("wrong message {other:?}"),
        }

        let round = g.usize_in(0, 1 << 20);
        let total = g.usize_in(1, 4096);
        let offset = g.usize_in(0, total);
        let wlen = g.usize_in(0, total - offset + 1);
        let weights = arbitrary_weights(g, wlen);
        let payloads = arbitrary_payloads(g);
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let f = wire::encode_peer_range_step(round, offset, total, &weights, &refs);
        match decode(&f).unwrap() {
            wire::Message::PeerRangeStep { round: r, offset: o, total: t, weights: w, payloads: p } => {
                assert_eq!(r, round);
                assert_eq!(o, offset);
                assert_eq!(t, total);
                assert_bits_eq(&w, &weights);
                assert_eq!(p, payloads);
            }
            other => panic!("wrong message {other:?}"),
        }

        match decode(&wire::encode_peer_slice(round, offset, total, &weights)).unwrap() {
            wire::Message::PeerSlice { round: r, offset: o, total: t, weights: w } => {
                assert_eq!(r, round);
                assert_eq!(o, offset);
                assert_eq!(t, total);
                assert_bits_eq(&w, &weights);
            }
            other => panic!("wrong message {other:?}"),
        }

        let rlen = g.usize_in(0, 2048);
        let replica = arbitrary_weights(g, rlen);
        match decode(&wire::encode_peer_replica_step(round, &replica, &refs)).unwrap() {
            wire::Message::PeerReplicaStep { round: r, weights: w, payloads: p } => {
                assert_eq!(r, round);
                assert_bits_eq(&w, &replica);
                assert_eq!(p, payloads);
            }
            other => panic!("wrong message {other:?}"),
        }

        match decode(&wire::encode_peer_replica_sync(round, &replica)).unwrap() {
            wire::Message::PeerReplicaSync { round: r, weights: w } => {
                assert_eq!(r, round);
                assert_bits_eq(&w, &replica);
            }
            other => panic!("wrong message {other:?}"),
        }
    });
}

/// Corruption coverage for the peer frames: any flipped byte is a decode
/// error, exactly like the client-facing frames.
#[test]
fn corrupted_peer_frames_rejected_property() {
    prop_check("wire peer corruption rejected", 60, |g| {
        let weights = g.vec_f32(1..256, -2.0, 2.0);
        let payloads = arbitrary_payloads(g);
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let frame = match g.usize_in(0, 4) {
            0 => wire::encode_peer_range_step(3, 0, weights.len(), &weights, &refs),
            1 => wire::encode_peer_slice(3, 0, weights.len(), &weights),
            2 => wire::encode_peer_replica_step(3, &weights, &refs),
            _ => wire::encode_peer_membership(&PeerMembership {
                member: 1,
                n_ps: 2,
                mode: PsMode::Range,
                sync_every: 1,
                d: 128,
                shards: 2,
                spec: arbitrary_spec(g),
            }),
        };
        let mut bad = frame.clone();
        let at = g.usize_in(0, bad.len());
        let flip = 1 + (g.rng.next_u64() % 255) as u8;
        bad[at] ^= flip;
        assert!(decode(&bad).is_err(), "byte {at} xor {flip:#x} accepted");
    });
}

/// The `FrameKind` boundary: every assigned byte round-trips through the
/// enum, the assigned range is contiguous from 1, and every unassigned
/// byte is a typed [`FrameError::UnknownKind`] carrying the offender —
/// the cap moves ONLY by adding a variant to the enum.
#[test]
fn frame_kind_bytes_roundtrip_and_unassigned_bytes_are_typed_errors() {
    let max = FrameKind::ALL.iter().map(|k| k.as_u8()).max().unwrap();
    assert_eq!(FrameKind::ALL.len() as u8, max, "kind bytes are not contiguous from 1");
    for k in FrameKind::ALL {
        assert_eq!(FrameKind::try_from(k.as_u8()).unwrap(), k);
    }
    for b in (0..=255u8).filter(|&b| b == 0 || b > max) {
        assert_eq!(FrameKind::try_from(b), Err(FrameError::UnknownKind { kind: b }));
    }
}

#[test]
fn framed_rate_accounting_matches_the_wire() {
    // RateReport::framed_total_bits with UPDATE_OVERHEAD reports exactly the
    // bytes an error-free update occupies on the wire
    let payload = vec![7u8; 321];
    let up = Uplink {
        client_id: 2,
        round: 5,
        payload: payload.clone(),
        report: RateReport { payload_bytes: payload.len(), ..Default::default() },
        train_loss: 0.0,
        error: None,
    };
    let frame = encode_update(&up);
    assert_eq!(frame.len() as u64 * 8, up.report.framed_total_bits(wire::UPDATE_OVERHEAD));
}
