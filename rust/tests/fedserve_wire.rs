//! Integration: the fedserve wire protocol round-trips arbitrary payloads
//! bit-exactly and rejects every corruption we can throw at it.

use m22::compress::RateReport;
use m22::coordinator::Uplink;
use m22::fedserve::wire::{
    self, decode, decode_prefix, encode_round, encode_shutdown, encode_update,
};
use m22::util::prop::prop_check;

#[test]
fn round_frames_roundtrip_property() {
    prop_check("wire round roundtrip", 60, |g| {
        let round = g.usize_in(0, 1_000_000);
        let mut weights = g.vec_f32(0..2000, -1e6, 1e6);
        // sprinkle special values — the frame must carry raw bits
        if !weights.is_empty() {
            weights[0] = f32::NAN;
            let n = weights.len();
            weights[n - 1] = -0.0;
        }
        let frame = encode_round(round, &weights);
        match decode(&frame).unwrap() {
            wire::Message::Round { round: r, weights: w } => {
                assert_eq!(r, round);
                assert_eq!(w.len(), weights.len());
                for (a, b) in w.iter().zip(&weights) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            other => panic!("wrong message {other:?}"),
        }
    });
}

fn arbitrary_uplink(g: &mut m22::util::prop::Gen) -> Uplink {
    let n = g.usize_in(0, 4096);
    let payload: Vec<u8> = (0..n).map(|_| (g.rng.next_u64() & 0xff) as u8).collect();
    let error = if g.bool() {
        None
    } else {
        Some(format!("client exploded at step {}", g.usize_in(0, 100)))
    };
    Uplink {
        client_id: g.usize_in(0, 10_000),
        round: g.usize_in(0, 10_000),
        payload,
        report: RateReport {
            d: g.usize_in(1, 1_000_000),
            k: g.usize_in(0, 500_000),
            position_bits_ideal: g.f64_in(0.0, 1e9),
            position_bits_actual: g.usize_in(0, 1_000_000) as u64,
            value_bits: g.usize_in(0, 1_000_000) as u64,
            side_bits: g.usize_in(0, 10_000) as u64,
            payload_bytes: g.usize_in(0, 4096),
        },
        train_loss: g.f64_in(-10.0, 10.0),
        error,
    }
}

#[test]
fn update_frames_roundtrip_property() {
    prop_check("wire update roundtrip", 60, |g| {
        let up = arbitrary_uplink(g);
        let frame = encode_update(&up);
        match decode(&frame).unwrap() {
            wire::Message::Update(u) => {
                assert_eq!(u.client_id, up.client_id);
                assert_eq!(u.round, up.round);
                assert_eq!(u.payload, up.payload);
                assert_eq!(u.train_loss.to_bits(), up.train_loss.to_bits());
                assert_eq!(u.error, up.error);
                assert_eq!(u.report.d, up.report.d);
                assert_eq!(u.report.k, up.report.k);
                assert_eq!(
                    u.report.position_bits_ideal.to_bits(),
                    up.report.position_bits_ideal.to_bits()
                );
                assert_eq!(u.report.position_bits_actual, up.report.position_bits_actual);
                assert_eq!(u.report.value_bits, up.report.value_bits);
                assert_eq!(u.report.side_bits, up.report.side_bits);
                assert_eq!(u.report.payload_bytes, up.report.payload_bytes);
            }
            other => panic!("wrong message {other:?}"),
        }
    });
}

#[test]
fn corrupted_frames_rejected_property() {
    prop_check("wire corruption rejected", 80, |g| {
        let frame = if g.bool() {
            encode_update(&arbitrary_uplink(g))
        } else {
            encode_round(g.usize_in(0, 100), &g.vec_f32(1..256, -2.0, 2.0))
        };
        let mut bad = frame.clone();
        let at = g.usize_in(0, bad.len());
        let flip = 1 + (g.rng.next_u64() % 255) as u8;
        bad[at] ^= flip;
        assert!(decode(&bad).is_err(), "byte {at} xor {flip:#x} accepted");
    });
}

#[test]
fn truncation_rejected_property() {
    prop_check("wire truncation rejected", 40, |g| {
        let frame = encode_round(g.usize_in(0, 100), &g.vec_f32(1..512, -2.0, 2.0));
        let cut = g.usize_in(0, frame.len());
        assert!(decode(&frame[..cut]).is_err(), "truncation to {cut} accepted");
    });
}

#[test]
fn streaming_reader_walks_mixed_frames() {
    let mut buf = Vec::new();
    let frames = vec![
        encode_round(0, &[1.0, 2.0]),
        encode_update(&Uplink {
            client_id: 1,
            round: 0,
            payload: vec![9, 9, 9],
            report: RateReport::default(),
            train_loss: 0.5,
            error: None,
        }),
        encode_shutdown(),
    ];
    for f in &frames {
        buf.extend_from_slice(f);
    }
    let mut off = 0;
    let mut seen = Vec::new();
    while off < buf.len() {
        let (msg, used) = decode_prefix(&buf[off..]).unwrap();
        off += used;
        seen.push(msg);
    }
    assert_eq!(off, buf.len());
    assert_eq!(seen.len(), 3);
    assert!(matches!(seen[0], wire::Message::Round { .. }));
    assert!(matches!(seen[1], wire::Message::Update(_)));
    assert!(matches!(seen[2], wire::Message::Shutdown));
}

#[test]
fn framed_rate_accounting_matches_the_wire() {
    // RateReport::framed_total_bits with UPDATE_OVERHEAD reports exactly the
    // bytes an error-free update occupies on the wire
    let payload = vec![7u8; 321];
    let up = Uplink {
        client_id: 2,
        round: 5,
        payload: payload.clone(),
        report: RateReport { payload_bytes: payload.len(), ..Default::default() },
        train_loss: 0.0,
        error: None,
    };
    let frame = encode_update(&up);
    assert_eq!(frame.len() as u64 * 8, up.report.framed_total_bits(wire::UPDATE_OVERHEAD));
}
