//! Integration: multi-PS sharding (`fedserve::cluster`).
//!
//! The acceptance oracle for the PR: a model-parallel (range) cluster at
//! n_ps ∈ {1, 2, 4} must be **bit-exact** against the single-PS reference
//! for every registered scheme, over both the channel and TCP-loopback
//! transports — partitioning the aggregation across PS instances reorders
//! *ownership*, never arithmetic. On top of that:
//!
//! * a one-replica client-partitioned cluster reproduces the single
//!   server bit-exactly (the partition sorts its subsets and
//!   `Scheduler::sample_of` is the same shuffle-prefix as `sample`);
//! * the client partition is a true partition — every client owned by
//!   exactly one PS, union = all, deterministic across replays from one
//!   seed (property-swept);
//! * a replica cluster under a straggler + disconnect storm degrades
//!   (drops + attributed decode errors), never aborts, keeps serving the
//!   healthy remainder, and its per-client `bytes_down` ledger matches
//!   the socket-measured transport truth (ISSUE 5);
//! * queued-but-undelivered downlink bytes to a dead peer are reconciled
//!   out of the ledger (the `bytes_down` "ledger lies" fix).

use std::net::TcpListener;
use std::sync::mpsc;
use std::time::Duration;

use m22::compress::{encode_once, NoCompression};
use m22::config::{ClusterConfig, ExperimentConfig, PsMode, Scheme, ServerConfig};
use m22::coordinator::Uplink;
use m22::fedserve::sim::{sim_spec, simulate_with, TransportMode};
use m22::fedserve::transport::{TcpClientTransport, TcpServerTransport, Transport};
use m22::fedserve::{partition_clients, wire, FedServer, PsCluster, Scheduler};
use m22::quantizer::Family;

const NET_TIMEOUT: Duration = Duration::from_secs(30);

fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for i in 0..a.len() {
        assert_eq!(a[i].to_bits(), b[i].to_bits(), "{what}: dim {i}");
    }
}

fn base_cfg(scheme: Scheme) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::new("sim", scheme, 2, 2);
    cfg.n_clients = 4;
    cfg.server.shards = 2;
    cfg.server.straggler_timeout_ms = 30_000;
    cfg.server.prewarm = false; // grid design is not what this suite times
    cfg
}

fn all_schemes() -> Vec<Scheme> {
    vec![
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ]
}

#[test]
fn range_cluster_is_bit_exact_against_the_single_ps_for_every_scheme() {
    let d = 512;
    for scheme in all_schemes() {
        let cfg = base_cfg(scheme);
        let single = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
        assert!(single.w.iter().any(|&x| x != 0.0), "{scheme:?}: reference did nothing");
        assert!(single.cluster.is_none());
        for transport in [TransportMode::Channel, TransportMode::TcpLoopback] {
            for n_ps in [1usize, 2, 4] {
                let mut c = cfg.clone();
                c.server.cluster =
                    Some(ClusterConfig::builder().n_ps(n_ps).mode(PsMode::Range).build());
                let rep = simulate_with(&c, d, transport).unwrap();
                assert_bitwise_eq(
                    &single.w,
                    &rep.w,
                    &format!("{scheme:?} n_ps={n_ps} {transport:?}"),
                );
                let cs = rep.cluster.expect("cluster rollup missing");
                assert_eq!(cs.n_ps(), n_ps, "{scheme:?}");
                assert_eq!(cs.mode, "range");
                // every PS recorded every round, nobody dropped anything
                for ps in &cs.per_ps {
                    assert_eq!(ps.rounds.len(), cfg.rounds, "{scheme:?} n_ps={n_ps}");
                    assert_eq!(ps.total_dropped(), 0, "{scheme:?} n_ps={n_ps}");
                }
                assert_eq!(rep.stats.total_dropped(), 0);
                assert!(rep.stats.total_framed_bytes() > 0);
            }
        }
    }
}

#[test]
fn one_replica_cluster_reproduces_the_single_server_bitwise() {
    // client-partitioned mode with one PS owns every client: schedule,
    // reduce, and sync must collapse to the single-server loop exactly —
    // at every sync cadence (1 = each round, 2 = mid-run, 0 = end only)
    let d = 640;
    for scheme in [
        Scheme::M22 { family: Family::GenNorm, m: 2.0 },
        Scheme::TopKUniform,
        Scheme::None,
    ] {
        let mut cfg = base_cfg(scheme);
        cfg.rounds = 3;
        let single = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
        for sync_every in [1usize, 2, 0] {
            let mut c = cfg.clone();
            c.server.cluster = Some(
                ClusterConfig::builder().n_ps(1).mode(PsMode::Replica).sync_every(sync_every).build(),
            );
            let rep = simulate_with(&c, d, TransportMode::Channel).unwrap();
            assert_bitwise_eq(
                &single.w,
                &rep.w,
                &format!("{scheme:?} replica-of-1 sync_every={sync_every}"),
            );
        }
    }
}

#[test]
fn replica_cluster_converges_on_the_sim_workload() {
    // multi-replica mode is not bit-equal to a single PS (that is the
    // point: each PS averages only its own client subset between syncs),
    // but it must run to completion, sync deterministically, and produce
    // the same model when replayed from the same seed
    let d = 768;
    let mut cfg = base_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 });
    cfg.n_clients = 8;
    cfg.rounds = 4;
    cfg.memory = true;
    cfg.server.cluster =
        Some(ClusterConfig::builder().n_ps(2).mode(PsMode::Replica).sync_every(2).build());
    let a = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
    let b = simulate_with(&cfg, d, TransportMode::Channel).unwrap();
    assert_bitwise_eq(&a.w, &b.w, "replica replay");
    assert!(a.w_norm() > 0.0 && a.w_norm().is_finite());
    let cs = a.cluster.expect("rollup");
    assert_eq!(cs.n_ps(), 2);
    assert_eq!(cs.sync_every, 2);
    // the partition routed every uplink to exactly one PS
    let per_ps: usize = cs.per_ps.iter().map(|p| p.total_received()).sum();
    assert_eq!(per_ps, a.stats.total_received());
    assert!(cs.per_ps.iter().all(|p| p.total_received() > 0));
}

#[test]
fn client_partition_property_sweep() {
    // every client owned by exactly one PS, union = all, balanced within
    // one, deterministic across replays from one seed — and per-PS
    // sampling stays inside the owned subset
    for n in [1usize, 2, 5, 16, 33, 64] {
        for n_ps in [1usize, 2, 3, 4, 7] {
            for seed in [1u64, 33, 4242] {
                let owned = partition_clients(n, n_ps, seed);
                assert_eq!(owned.len(), n_ps);
                let mut all: Vec<usize> = owned.iter().flatten().copied().collect();
                all.sort_unstable();
                assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n} n_ps={n_ps} seed={seed}");
                let max = owned.iter().map(Vec::len).max().unwrap();
                let min = owned.iter().map(Vec::len).min().unwrap();
                assert!(max - min <= 1, "n={n} n_ps={n_ps}: unbalanced");
                assert_eq!(owned, partition_clients(n, n_ps, seed), "replay differs");
                // per-PS schedulers sample within their subset, and the
                // same seed replays the same schedule
                for (i, pool) in owned.iter().enumerate() {
                    if pool.is_empty() {
                        continue;
                    }
                    let mut s1 = Scheduler::new(seed.wrapping_add(i as u64));
                    let mut s2 = Scheduler::new(seed.wrapping_add(i as u64));
                    for _ in 0..3 {
                        let k = (pool.len() / 2).max(1);
                        let sample = s1.sample_of(pool, k);
                        assert_eq!(sample, s2.sample_of(pool, k));
                        assert_eq!(sample.len(), k);
                        assert!(sample.iter().all(|id| pool.contains(id)));
                    }
                }
            }
        }
    }
}

#[test]
fn replica_storm_degrades_attributes_and_reconciles_the_ledger() {
    // 12 clients on a 2-PS replica cluster over real sockets: 8 healthy,
    // 2 leave after round 0, 1 answers every round with a corrupt frame,
    // 1 connects and never responds. Rounds must complete on the
    // deadline, failures must be attributed per client, the next round
    // must serve the healthy remainder — and the per-client downlink
    // ledger must equal the socket-measured transport truth.
    let n = 12usize;
    let healthy = 8usize; // ids 0..8
    let leavers = 2usize; // ids 8..10
    let corrupt_id = 10usize;
    let straggler_id = 11usize;
    let d = 128usize;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::scope(|scope| {
        for id in 0..n {
            let addr = addr.clone();
            let spec = &spec;
            scope.spawn(move || {
                let mut t = TcpClientTransport::connect(&addr, id, NET_TIMEOUT).unwrap();
                loop {
                    match t.recv() {
                        Ok(Some(wire::Message::Round { round, .. })) => {
                            if id == straggler_id {
                                continue; // reads rounds, never replies
                            }
                            let g = vec![(id + 1) as f32; d];
                            let (payload, _, report) =
                                encode_once(&NoCompression, &g, spec).unwrap();
                            let up = Uplink {
                                client_id: id,
                                round,
                                payload,
                                report,
                                train_loss: 0.0,
                                error: None,
                            };
                            let mut f = wire::encode_update(&up);
                            if id == corrupt_id {
                                let at = f.len() / 2;
                                f[at] ^= 0x01;
                            }
                            if t.send(&f).is_err() {
                                return; // server closed us (expected)
                            }
                            if id >= healthy && id < healthy + leavers {
                                return; // storm: vanish after round 0
                            }
                        }
                        _ => return, // shutdown or server-side close
                    }
                }
            });
        }

        let mut transport = TcpServerTransport::accept(&listener, n, NET_TIMEOUT).unwrap();
        let scfg = ServerConfig { straggler_timeout_ms: 800, ..Default::default() };
        let ccfg = ClusterConfig::builder().n_ps(2).mode(PsMode::Replica).sync_every(2).build();
        let decoders = (0..2)
            .map(|_| Box::new(NoCompression) as Box<dyn m22::compress::Decoder>)
            .collect();
        let mut cluster = PsCluster::new(&ccfg, &scfg, n, d, 1, decoders).unwrap();
        let mut w = vec![0.0f32; d];
        let s0 = cluster.run_round(0, n, &mut transport, &spec, &mut w).unwrap();
        // round 0: everyone but the corrupt frame and the silent straggler
        assert_eq!(s0.received, n - 2);
        assert_eq!(s0.decode_errors, 1);
        assert_eq!(s0.dropped, 2);
        assert_eq!(cluster.sessions[corrupt_id].decode_errors, 1);
        for id in 0..n {
            if id != corrupt_id {
                assert_eq!(cluster.sessions[id].decode_errors, 0, "client {id}");
            }
        }
        // round 1: the leavers and the corrupt client are gone too
        let s1 = cluster.run_round(1, n, &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s1.received, healthy);
        assert_eq!(s1.decode_errors, 0);
        assert_eq!(s1.dropped, n - healthy);
        cluster.finish(&mut w);
        assert!(w.iter().any(|&x| x != 0.0), "storm starved the aggregate");

        // ISSUE 5: the downlink ledger equals the socket truth, per client
        // (snapshot before close so shutdown frames don't skew the diff)
        let ts = transport.stats();
        assert!(ts.socket_measured);
        // the leavers' EOFs are observed disconnects (the corrupt stream's
        // kill is counted under decode_errors instead)
        assert!(ts.disconnects >= leavers as u64, "{} disconnects", ts.disconnects);
        assert_eq!(ts.decode_errors, 1);
        for id in 0..n {
            assert_eq!(
                cluster.sessions[id].bytes_down,
                ts.per_client[id].1,
                "client {id}: ledger vs socket"
            );
        }
        // per-PS rollup recorded both rounds
        let cs = cluster.cluster_stats();
        assert_eq!(cs.n_ps(), 2);
        for ps in &cs.per_ps {
            assert_eq!(ps.rounds.len(), 2);
        }
        transport.close().unwrap();
    });
}

#[test]
fn queued_bytes_to_a_dead_peer_are_reconciled_out_of_the_ledger() {
    // a broadcast far larger than the kernel buffers to a peer that never
    // reads: send-time crediting would claim the whole frame was
    // delivered; the reconciled ledger must report the socket truth
    // ~16 MB round frame: comfortably past anything the kernel will
    // buffer for a peer that never reads, so part of the broadcast is
    // still queued (and then discarded) when the round ends
    let d = 4_000_000usize;
    let spec = sim_spec(d);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let (release_tx, release_rx) = mpsc::channel::<()>();
    std::thread::scope(|scope| {
        {
            let addr = addr.clone();
            scope.spawn(move || {
                // client 0 serves the round honestly
                let mut t = TcpClientTransport::connect(&addr, 0, NET_TIMEOUT).unwrap();
                if let Ok(Some(wire::Message::Round { round, .. })) = t.recv() {
                    let g = vec![1.0f32; d];
                    let (payload, _, report) = encode_once(&NoCompression, &g, &spec).unwrap();
                    let up = Uplink {
                        client_id: 0,
                        round,
                        payload,
                        report,
                        train_loss: 0.0,
                        error: None,
                    };
                    let _ = t.send(&wire::encode_update(&up));
                }
                let _ = t.recv(); // shutdown / close
            });
        }
        scope.spawn(move || {
            // client 1 connects, then stops reading entirely
            let t = TcpClientTransport::connect(&addr, 1, NET_TIMEOUT).unwrap();
            let _ = release_rx.recv();
            drop(t);
        });

        let mut transport = TcpServerTransport::accept(&listener, 2, NET_TIMEOUT).unwrap();
        let cfg = ServerConfig { straggler_timeout_ms: 2_000, ..Default::default() };
        let mut server = FedServer::new(cfg, 2, 1, Box::new(NoCompression));
        let mut w = vec![0.0f32; d];
        let frame_len = wire::encode_round(0, &w).len() as u64;
        let s = server.run_round(0, &[0, 1], &mut transport, &spec, &mut w).unwrap();
        assert_eq!(s.received, 1);
        assert_eq!(s.dropped, 1);
        let ts = transport.stats();
        // client 0 drained the whole broadcast
        assert_eq!(server.sessions[0].bytes_down, ts.per_client[0].1);
        assert_eq!(server.sessions[0].bytes_down, frame_len);
        // client 1 took only what the kernel buffered: the ledger was
        // reconciled down from the full frame to the socket truth
        assert_eq!(server.sessions[1].bytes_down, ts.per_client[1].1);
        assert!(
            server.sessions[1].bytes_down < frame_len,
            "ledger still credits undelivered bytes: {} vs frame {}",
            server.sessions[1].bytes_down,
            frame_len
        );
        assert!(server.sessions[1].bytes_down > 0, "nothing at all reached client 1");
        release_tx.send(()).unwrap();
        transport.close().unwrap();
    });
}
