//! Integration: full federated rounds through PS + client threads + PJRT.

use std::path::PathBuf;

use m22::config::{presets, ExperimentConfig, Scheme};
use m22::coordinator::run_experiment;
use m22::data::Dataset;
use m22::metrics::Recorder;
use m22::quantizer::Family;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! skip_without_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts not built");
                return;
            }
        }
    };
}

fn handle() -> m22::runtime::RuntimeHandle {
    use std::sync::OnceLock;
    static HANDLE: OnceLock<m22::runtime::RuntimeHandle> = OnceLock::new();
    HANDLE
        .get_or_init(|| m22::runtime::spawn(artifacts_dir().unwrap()).expect("runtime spawn"))
        .clone()
}

fn tiny_cfg(scheme: Scheme, rounds: usize) -> ExperimentConfig {
    let mut cfg = presets::quickstart("cnn_s", rounds);
    cfg.scheme = scheme;
    cfg.local_steps = 2;
    cfg.eval_batches = 2;
    cfg.dataset.train_per_class = 48;
    cfg.dataset.test_per_class = 8;
    cfg
}

#[test]
fn m22_federated_run_learns() {
    skip_without_artifacts!();
    let cfg = tiny_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 6);
    let dataset = Dataset::generate(cfg.dataset);
    let mut rec = Recorder::new();
    let out = run_experiment(&cfg, &handle(), &dataset, "m22", &mut rec).unwrap();
    assert_eq!(out.rounds, 6);
    assert!(out.final_test_acc > 0.15, "no learning: acc {}", out.final_test_acc);
    // loss decreased from round 0
    let curve = rec.acc_curve("m22");
    assert_eq!(curve.len(), 6);
    let first_loss = rec.rows.first().unwrap().test_loss;
    assert!(out.final_test_loss < first_loss, "{} -> {}", first_loss, out.final_test_loss);
    assert!(out.bits_per_round > 0.0);
}

#[test]
fn all_schemes_run_one_round() {
    skip_without_artifacts!();
    let schemes = [
        Scheme::M22 { family: Family::Weibull, m: 4.0 },
        Scheme::TinyScript,
        Scheme::TopKUniform,
        Scheme::TopKFp { bits: 8 },
        Scheme::TopKFp { bits: 4 },
        Scheme::CountSketch,
        Scheme::None,
    ];
    let dataset = Dataset::generate(tiny_cfg(Scheme::None, 1).dataset);
    let mut rec = Recorder::new();
    for scheme in schemes {
        let cfg = tiny_cfg(scheme, 1);
        let label = cfg.scheme.label(cfg.rq);
        let out = run_experiment(&cfg, &handle(), &dataset, &label, &mut rec).unwrap();
        assert!(out.final_test_loss.is_finite(), "{label}");
    }
    assert_eq!(rec.series_names().len(), schemes.len());
}

#[test]
fn uncompressed_spends_far_more_bits() {
    skip_without_artifacts!();
    let dataset = Dataset::generate(tiny_cfg(Scheme::None, 1).dataset);
    let mut rec = Recorder::new();
    let o_none =
        run_experiment(&tiny_cfg(Scheme::None, 1), &handle(), &dataset, "none", &mut rec).unwrap();
    let o_m22 = run_experiment(
        &tiny_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 1),
        &handle(),
        &dataset,
        "m22",
        &mut rec,
    )
    .unwrap();
    assert!(o_none.bits_per_round > 8.0 * o_m22.bits_per_round);
}

#[test]
fn memory_variant_runs() {
    skip_without_artifacts!();
    let mut cfg = tiny_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 3);
    cfg.memory = true;
    cfg.memory_decay = 0.5;
    let dataset = Dataset::generate(cfg.dataset);
    let mut rec = Recorder::new();
    let out = run_experiment(&cfg, &handle(), &dataset, "m22+mem", &mut rec).unwrap();
    assert!(out.final_test_loss.is_finite());
}

#[test]
fn deterministic_across_runs() {
    skip_without_artifacts!();
    let cfg = tiny_cfg(Scheme::M22 { family: Family::GenNorm, m: 2.0 }, 2);
    let dataset = Dataset::generate(cfg.dataset);
    let mut r1 = Recorder::new();
    let mut r2 = Recorder::new();
    let o1 = run_experiment(&cfg, &handle(), &dataset, "a", &mut r1).unwrap();
    let o2 = run_experiment(&cfg, &handle(), &dataset, "a", &mut r2).unwrap();
    assert_eq!(o1.final_test_acc, o2.final_test_acc);
    assert_eq!(o1.final_test_loss, o2.final_test_loss);
}
