//! Parity suite for the kernel backends (`compress::kernels`): the SIMD
//! backend is pinned against the scalar reference, first at the raw
//! kernel surface (fuzzed inputs, lengths straddling the 8-lane width)
//! and then end-to-end through every registered scheme.
//!
//! Contract under test (see the `compress::kernels` module docs):
//!
//! * `quantize_block`, `pack`, `unpack` — **bit-exact** for every input,
//!   zeros / −0.0 / threshold ties / ±∞ / NaN included.
//! * `scatter_add`, `scatter_add_range` — documented ULP bound is **0**
//!   (serial adds, vectorized multiply with identical rounding), so the
//!   reductions are asserted bitwise as well.
//!
//! On hosts without a SIMD backend the cross-backend assertions are
//! vacuous: each test prints a note and returns, and the scalar
//! reference — the only backend there — is covered by the rest of the
//! test suite (plus the forced-scalar CI lane on hosts that *do* have
//! SIMD).

use std::sync::Arc;

use m22::compress::kernels::{self, Kernels, QuantBlock};
use m22::compress::registry::{self, Scheme, SchemeSpec};
use m22::compress::{BlockCodec, Budget, CpuCodec, Decoder, EncodeCtx, Encoder, MAX_LEVELS};
use m22::fedserve::sim::sim_spec;
use m22::quantizer::{QuantizerTables, TableSource};
use m22::util::prop::{prop_check, Gen};

/// Lengths that straddle the 8-lane width from every side: empty, below
/// one lane, exactly one lane, one off either boundary, several blocks
/// plus ragged tails.
const LENGTHS: &[usize] = &[0, 1, 3, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 257];

/// False (with a visible note) when this host has no SIMD backend to
/// compare against.
fn simd_or_skip(test: &str) -> bool {
    if kernels::simd_kernels().is_none() {
        eprintln!("{test}: no SIMD backend on this host — cross-backend parity is vacuous");
        return false;
    }
    true
}

/// Both backends, fetched inside prop closures (capturing the trait
/// objects would break `prop_check`'s `RefUnwindSafe` bound).
fn both() -> (&'static dyn Kernels, &'static dyn Kernels) {
    (kernels::scalar_kernels(), kernels::simd_kernels().unwrap())
}

fn assert_bitwise(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (j, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: dim {j}: {a} vs {b}");
    }
}

/// A random quantizer table in the blocked layout: `levels` live entries
/// (thresholds sorted, +∞-padded to 15; centers padded by repeating the
/// last), exactly what `TableSource::get_block` hands the kernels.
fn random_block(g: &mut Gen) -> QuantBlock {
    let levels = *g.pick(&[2usize, 4, 8, 16]);
    let mut cuts: Vec<f32> = (0..levels - 1).map(|_| g.f32_in(-3.0, 3.0)).collect();
    cuts.sort_by(f32::total_cmp);
    let mut thresholds = [f32::INFINITY; MAX_LEVELS - 1];
    thresholds[..levels - 1].copy_from_slice(&cuts);
    let mut centers = [0.0f32; MAX_LEVELS];
    for c in centers.iter_mut().take(levels) {
        *c = g.f32_in(-4.0, 4.0);
    }
    let last = centers[levels - 1];
    for c in centers.iter_mut().skip(levels) {
        *c = last;
    }
    QuantBlock { thresholds, centers }
}

/// Gradient values with the awkward cases injected: exact zeros, −0.0,
/// ±∞, NaN, and exact threshold ties (where searchsorted side=right is
/// the one tie-break both backends must share).
fn awkward_values(g: &mut Gen, n: usize, blk: &QuantBlock) -> Vec<f32> {
    (0..n)
        .map(|_| {
            if g.rng.below(5) == 0 {
                match g.rng.below(6) {
                    0 => 0.0,
                    1 => -0.0,
                    2 => f32::INFINITY,
                    3 => f32::NEG_INFINITY,
                    4 => f32::NAN,
                    _ => blk.thresholds[g.rng.below(MAX_LEVELS - 1)],
                }
            } else {
                g.f32_in(-4.0, 4.0)
            }
        })
        .collect()
}

#[test]
fn quantize_block_scalar_vs_simd_bitwise() {
    if !simd_or_skip("quantize_block parity") {
        return;
    }
    prop_check("quantize_block scalar ≡ simd", 30, |g| {
        let (sc, sd) = both();
        let blk = random_block(g);
        for &n in LENGTHS {
            let v = awkward_values(g, n, &blk);
            let mut idx_a = vec![0u32; n];
            let mut ghat_a = vec![0.0f32; n];
            let mut idx_b = vec![u32::MAX; n];
            let mut ghat_b = vec![-9.0f32; n];
            sc.quantize_block(&v, &blk.thresholds, &blk.centers, &mut idx_a, &mut ghat_a);
            sd.quantize_block(&v, &blk.thresholds, &blk.centers, &mut idx_b, &mut ghat_b);
            assert_eq!(idx_a, idx_b, "idx diverges at n={n}");
            assert_bitwise(&ghat_b, &ghat_a, &format!("ghat at n={n}"));
            // ... and both agree with the one searchsorted rule
            for (j, (&x, &i)) in v.iter().zip(&idx_a).enumerate() {
                let want = if x == 0.0 {
                    0
                } else {
                    kernels::nearest_center_f32(&blk.thresholds, x)
                };
                assert_eq!(i as usize, want, "searchsorted rule at n={n} j={j} x={x}");
            }
        }
    });
}

#[test]
fn pack_scalar_vs_simd_byte_identical() {
    if !simd_or_skip("pack parity") {
        return;
    }
    prop_check("pack scalar ≡ simd", 30, |g| {
        let (sc, sd) = both();
        let bits = g.usize_in(1, 33) as u32;
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        for &n in LENGTHS {
            let codes: Vec<u32> = (0..n).map(|_| g.rng.next_u64() as u32 & mask).collect();
            // both backends append after an existing byte-aligned prefix
            let prefix = vec![0x5au8; g.rng.below(4)];
            let mut a = prefix.clone();
            let mut b = prefix.clone();
            sc.pack(&codes, bits, &mut a);
            sd.pack(&codes, bits, &mut b);
            assert_eq!(a, b, "pack bytes diverge at bits={bits} n={n}");
            let want_len = prefix.len() + (n * bits as usize).div_ceil(8);
            assert_eq!(a.len(), want_len, "pack length at bits={bits} n={n}");
        }
    });
}

#[test]
fn unpack_scalar_vs_simd_including_offsets_and_truncation() {
    if !simd_or_skip("unpack parity") {
        return;
    }
    prop_check("unpack scalar ≡ simd", 30, |g| {
        let (sc, sd) = both();
        let bits = g.usize_in(1, 33) as u32;
        let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
        for &n in LENGTHS {
            let codes: Vec<u32> = (0..n).map(|_| g.rng.next_u64() as u32 & mask).collect();
            let mut bytes = Vec::new();
            sc.pack(&codes, bits, &mut bytes);
            // resume mid-stream at a random code boundary, like the
            // batched decode walk does
            let j = g.rng.below(n + 1);
            let off = j as u64 * bits as u64;
            let mut got_a = vec![0u32; n - j];
            let mut got_b = vec![u32::MAX; n - j];
            assert!(sc.unpack(&bytes, off, bits, &mut got_a), "scalar bits={bits} n={n} j={j}");
            assert!(sd.unpack(&bytes, off, bits, &mut got_b), "simd bits={bits} n={n} j={j}");
            assert_eq!(&got_a[..], &codes[j..], "scalar codes at bits={bits} n={n} j={j}");
            assert_eq!(&got_b[..], &codes[j..], "simd codes at bits={bits} n={n} j={j}");
            // a truncated stream starves both backends identically
            if j < n {
                let cut = &bytes[..bytes.len() - 1];
                let mut sink = vec![0u32; n - j];
                assert!(!sc.unpack(cut, off, bits, &mut sink), "scalar truncation n={n}");
                assert!(!sd.unpack(cut, off, bits, &mut sink), "simd truncation n={n}");
            }
        }
    });
}

#[test]
fn scatter_folds_scalar_vs_simd_bitwise() {
    if !simd_or_skip("scatter parity") {
        return;
    }
    prop_check("scatter_add(_range) scalar ≡ simd", 30, |g| {
        let (sc, sd) = both();
        let d = g.usize_in(1, 400);
        for &n in LENGTHS {
            // duplicate targets are likely (and intended): the fold order
            // over a repeated index is part of the contract
            let positions: Vec<u32> = (0..n).map(|_| g.rng.below(d) as u32).collect();
            let values = g.vec_f32(n..n + 1, -2.0, 2.0);
            for &w in &[1.0f32, 0.0, -1.5, 0.37] {
                let base = g.vec_f32(d..d + 1, -1.0, 1.0);
                let mut a = base.clone();
                let mut b = base.clone();
                sc.scatter_add(&positions, &values, w, &mut a);
                sd.scatter_add(&positions, &values, w, &mut b);
                assert_bitwise(&b, &a, &format!("scatter_add w={w} n={n} d={d}"));

                let offset = g.rng.below(d);
                let wlen = g.usize_in(1, d - offset + 1);
                let wbase = g.vec_f32(wlen..wlen + 1, -1.0, 1.0);
                let mut wa = wbase.clone();
                let mut wb = wbase.clone();
                sc.scatter_add_range(&positions, &values, w, offset, &mut wa);
                sd.scatter_add_range(&positions, &values, w, offset, &mut wb);
                assert_bitwise(&wb, &wa, &format!("scatter_add_range w={w} n={n} off={offset}"));
            }
        }
    });
}

fn build_pair_with(
    scheme: Scheme,
    b: &Budget,
    seed: u64,
    ks: &'static dyn Kernels,
) -> (Box<dyn Encoder>, Box<dyn Decoder>) {
    let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::with_kernels(ks));
    let tables: Arc<dyn TableSource> = Arc::new(QuantizerTables::new());
    let spec = SchemeSpec::new(scheme, 0, 0).resolve(b, seed);
    let enc = registry::build_encoder_with(&spec, codec.clone(), tables.clone(), ks).unwrap();
    let dec = registry::build_decoder_with(&spec, codec, tables, ks).unwrap();
    (enc, dec)
}

/// End-to-end invariance per registered scheme: same gradient through a
/// scalar-pinned and a SIMD-pinned stack must produce byte-identical
/// payloads, bitwise-identical reconstructions and dense decodes, and
/// bitwise-identical fused / windowed folds (windows concatenating to
/// the serial fold on either backend).
#[test]
fn every_scheme_is_backend_invariant_end_to_end() {
    if !simd_or_skip("scheme end-to-end parity") {
        return;
    }
    prop_check("all_schemes scalar ≡ simd end-to-end", 6, |g| {
        let (sc, sd) = both();
        let d = g.usize_in(300, 1600);
        let spec = sim_spec(d);
        let b = Budget::paper_point(d, *g.pick(&[1u32, 2, 3, 4]));
        let grad = g.grad_like(d..d + 1, g.f64_in(0.0, 0.6));
        let weight = *g.pick(&[0.37f32, -1.5, 2.25]);
        for scheme in registry::all_schemes() {
            let (enc_a, dec_a) = build_pair_with(scheme, &b, 7, sc);
            let (enc_b, dec_b) = build_pair_with(scheme, &b, 7, sd);
            let mut ctx_a = EncodeCtx::new();
            let mut ctx_b = EncodeCtx::new();
            enc_a.encode(&grad, &spec, &mut ctx_a).unwrap();
            enc_b.encode(&grad, &spec, &mut ctx_b).unwrap();
            assert_eq!(ctx_a.payload(), ctx_b.payload(), "{scheme:?}: payload bytes diverge");
            assert_bitwise(
                ctx_b.reconstructed(),
                ctx_a.reconstructed(),
                &format!("{scheme:?}: encoder reconstruction"),
            );
            // decode the same payload through both backends
            let dense_a = dec_a.decode_dense(ctx_a.payload(), &spec).unwrap();
            let dense_b = dec_b.decode_dense(ctx_a.payload(), &spec).unwrap();
            assert_bitwise(&dense_b, &dense_a, &format!("{scheme:?}: dense decode"));
            let acc0 = g.vec_f32(d..d + 1, -1.0, 1.0);
            for &w in &[1.0f32, weight] {
                // fused w·ĝ fold
                let mut aa = acc0.clone();
                let mut ab = acc0.clone();
                dec_a.decode_accumulate(ctx_a.payload(), &spec, w, &mut aa).unwrap();
                dec_b.decode_accumulate(ctx_a.payload(), &spec, w, &mut ab).unwrap();
                assert_bitwise(&ab, &aa, &format!("{scheme:?}: fused fold w={w}"));
                // eq.-(7) range reduce: two windows concatenate to the
                // serial fold, on either backend
                let cut = g.usize_in(1, d);
                let mut win_a = acc0[..cut].to_vec();
                let mut tail_a = acc0[cut..].to_vec();
                dec_a.decode_accumulate_range(ctx_a.payload(), &spec, w, 0, &mut win_a).unwrap();
                dec_a.decode_accumulate_range(ctx_a.payload(), &spec, w, cut, &mut tail_a).unwrap();
                let mut win_b = acc0[..cut].to_vec();
                let mut tail_b = acc0[cut..].to_vec();
                dec_b.decode_accumulate_range(ctx_a.payload(), &spec, w, 0, &mut win_b).unwrap();
                dec_b.decode_accumulate_range(ctx_a.payload(), &spec, w, cut, &mut tail_b).unwrap();
                win_a.extend_from_slice(&tail_a);
                win_b.extend_from_slice(&tail_b);
                assert_bitwise(&win_a, &aa, &format!("{scheme:?}: windowed ≡ serial w={w}"));
                assert_bitwise(&win_b, &win_a, &format!("{scheme:?}: windowed fold w={w}"));
            }
        }
    });
}

/// Degenerate gradient (every entry zero — survivors all quantize to the
/// zero bin) stays backend-invariant too: this is the smallest payload
/// the batched decode walk sees and the one where an off-by-one in the
/// empty/short batches would hide.
#[test]
fn all_zero_gradient_is_backend_invariant() {
    if !simd_or_skip("zero-gradient parity") {
        return;
    }
    let (sc, sd) = both();
    let d = 640;
    let spec = sim_spec(d);
    let b = Budget::paper_point(d, 2);
    let grad = vec![0.0f32; d];
    for scheme in registry::all_schemes() {
        let (enc_a, dec_a) = build_pair_with(scheme, &b, 3, sc);
        let (enc_b, dec_b) = build_pair_with(scheme, &b, 3, sd);
        let mut ctx_a = EncodeCtx::new();
        let mut ctx_b = EncodeCtx::new();
        enc_a.encode(&grad, &spec, &mut ctx_a).unwrap();
        enc_b.encode(&grad, &spec, &mut ctx_b).unwrap();
        assert_eq!(ctx_a.payload(), ctx_b.payload(), "{scheme:?}: zero-grad payload diverges");
        let mut acc_a = vec![0.25f32; d];
        let mut acc_b = acc_a.clone();
        dec_a.decode_accumulate(ctx_a.payload(), &spec, 0.37, &mut acc_a).unwrap();
        dec_b.decode_accumulate(ctx_a.payload(), &spec, 0.37, &mut acc_b).unwrap();
        assert_bitwise(&acc_b, &acc_a, &format!("{scheme:?}: zero-grad fold"));
    }
}

/// Empty inputs are exact no-ops on every backend — the kernel-level
/// face of the "empty survivors" case.
#[test]
fn empty_inputs_are_noops_on_every_backend() {
    let mut backends: Vec<&'static dyn Kernels> = vec![kernels::scalar_kernels()];
    backends.extend(kernels::simd_kernels());
    for ks in backends {
        let mut out = vec![0xa5u8; 2];
        ks.pack(&[], 7, &mut out);
        assert_eq!(out, vec![0xa5u8; 2], "{}: empty pack must append nothing", ks.name());
        assert!(ks.unpack(&[], 0, 7, &mut []), "{}: empty unpack succeeds", ks.name());
        let mut acc = vec![1.5f32; 3];
        ks.scatter_add(&[], &[], 2.0, &mut acc);
        ks.scatter_add_range(&[], &[], 2.0, 1, &mut acc);
        assert_eq!(acc, vec![1.5f32; 3], "{}: empty folds are no-ops", ks.name());
        let mut idx = [0u32; 0];
        let mut ghat = [0f32; 0];
        let blk = QuantBlock {
            thresholds: [f32::INFINITY; MAX_LEVELS - 1],
            centers: [0.0; MAX_LEVELS],
        };
        ks.quantize_block(&[], &blk.thresholds, &blk.centers, &mut idx, &mut ghat);
    }
}
