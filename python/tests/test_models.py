"""L2 model graph tests: shapes, gradients, learning signal, Table-I rows."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.archs import ARCHS, IMG, NUM_CLASSES
from compile.model import arch_summary, example_shapes, make_graphs
from compile.params import init_params, total_size, unflatten, flatten

BATCH = 8  # small batch for test speed; lowering uses BATCH=32


def _batch(rng, batch=BATCH):
    x = rng.normal(size=(batch, IMG, IMG, 3)).astype(np.float32)
    y = rng.integers(0, NUM_CLASSES, size=batch).astype(np.int32)
    return jnp.asarray(x), jnp.asarray(y)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_train_step_shapes(arch):
    specs, train_step, evaluate = make_graphs(arch)
    d = total_size(specs)
    w = init_params(specs, 0)
    assert w.shape == (d,)
    x, y = _batch(np.random.default_rng(0))
    loss, grads, acc = train_step(w, x, y)
    assert loss.shape == () and grads.shape == (d,) and acc.shape == ()
    assert np.isfinite(float(loss)) and 0.0 <= float(acc) <= 1.0
    assert np.isfinite(np.asarray(grads)).all()


@pytest.mark.parametrize("arch", list(ARCHS))
def test_grads_nonzero_in_every_tensor(arch):
    """Compression is per-layer — every tensor must receive gradient."""
    specs, train_step, _ = make_graphs(arch)
    w = init_params(specs, 1)
    x, y = _batch(np.random.default_rng(1))
    _, grads, _ = train_step(w, x, y)
    g = unflatten(grads, specs)
    for s in specs:
        assert float(jnp.abs(g[s.name]).max()) > 0.0, s.name


@pytest.mark.parametrize("arch", list(ARCHS))
def test_sgd_reduces_loss(arch):
    """A few SGD steps on one batch must reduce loss (learning signal)."""
    specs, train_step, _ = make_graphs(arch)
    w = init_params(specs, 2)
    x, y = _batch(np.random.default_rng(2), batch=16)
    step = jax.jit(train_step)
    l0, g, _ = step(w, x, y)
    for _ in range(5):
        w = w - 0.05 * g
        loss, g, _ = step(w, x, y)
    assert float(loss) < float(l0)


@pytest.mark.parametrize("arch", list(ARCHS))
def test_eval_matches_train_metrics(arch):
    specs, train_step, evaluate = make_graphs(arch)
    w = init_params(specs, 3)
    x, y = _batch(np.random.default_rng(3))
    l1, _, a1 = train_step(w, x, y)
    l2, a2 = evaluate(w, x, y)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2))


def test_flatten_unflatten_roundtrip():
    specs, _, _ = make_graphs("cnn_s")
    w = init_params(specs, 4)
    np.testing.assert_array_equal(flatten(unflatten(w, specs), specs), w)


def test_table1_summaries():
    """Table I analogue: structural facts the paper reports."""
    rows = {a: arch_summary(a) for a in ARCHS}
    # CNN: pure-conv feature extractor (dense only in the small classifier head)
    assert rows["cnn_s"]["conv_params"] > 0
    # VGG: parameter mass dominated by dense layers, like VGG16 in the paper
    assert rows["vgg_s"]["dense_params"] > rows["vgg_s"]["conv_params"]
    # ordering: CNN < ResNet < VGG, as in Table I
    assert (
        rows["cnn_s"]["total_params"]
        < rows["resnet_s"]["total_params"]
        < rows["vgg_s"]["total_params"]
    )


@pytest.mark.parametrize("arch", list(ARCHS))
def test_example_shapes_consistent(arch):
    specs, _, _ = make_graphs(arch)
    w_s, x_s, y_s = example_shapes(arch)
    assert w_s.shape == (total_size(specs),)
    assert x_s.shape[1:] == (IMG, IMG, 3)
    assert y_s.shape == (x_s.shape[0],)
