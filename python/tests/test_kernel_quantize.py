"""Hypothesis sweep of the quantizer-assignment kernel vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

CHUNK = 4096
L = K.MAX_LEVELS


def _quantizer(rng, levels: int):
    """Random padded (thresholds, centers) with `levels` live levels."""
    c = np.sort(rng.normal(size=levels)).astype(np.float32)
    t = ((c[1:] + c[:-1]) / 2).astype(np.float32)
    c_pad = np.concatenate([c, np.full(L - levels, c[-1], np.float32)])
    t_pad = np.concatenate([t, np.full(L - levels, np.float32(np.inf))])
    return t_pad[: L - 1], c_pad


@given(
    seed=st.integers(0, 2**31 - 1),
    levels=st.sampled_from([2, 4, 8, 16]),
    sparsity=st.floats(0.0, 0.95),
    nblocks=st.integers(1, 3),
)
def test_quantize_matches_oracle(seed, levels, sparsity, nblocks):
    rng = np.random.default_rng(seed)
    n = CHUNK * nblocks
    g = rng.normal(size=n).astype(np.float32)
    g[rng.random(n) < sparsity] = 0.0
    t, c = _quantizer(rng, levels)
    idx, ghat = K.quantize_block(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    ri, rh = ref.quantize_ref(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_allclose(np.asarray(ghat), np.asarray(rh))
    # live-level invariant: indices stay inside the live range
    assert int(np.asarray(idx).max()) < levels


@given(seed=st.integers(0, 2**31 - 1))
def test_quantize_zeros_survive(seed):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=CHUNK).astype(np.float32)
    g[::2] = 0.0
    t, c = _quantizer(rng, 8)
    idx, ghat = K.quantize_block(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    ghat = np.asarray(ghat)
    assert (ghat[::2] == 0.0).all()
    assert (np.asarray(idx)[::2] == 0).all()


def test_quantize_nearest_center_when_midpoint_thresholds():
    """With midpoint thresholds, assignment must be nearest-center."""
    rng = np.random.default_rng(7)
    g = rng.normal(size=CHUNK).astype(np.float32)
    t, c = _quantizer(rng, 16)
    _, ghat = K.quantize_block(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    ghat = np.asarray(ghat)
    nz = g != 0
    best = c[np.argmin(np.abs(g[:, None] - c[None, :]), axis=1)]
    np.testing.assert_allclose(ghat[nz], best[nz])


def test_quantize_reconstruction_error_bounded():
    rng = np.random.default_rng(8)
    g = rng.normal(size=CHUNK).astype(np.float32)
    t, c = _quantizer(rng, 16)
    _, ghat = K.quantize_block(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    err = np.abs(np.asarray(ghat) - g)
    # inside the center span the error is at most the largest half-gap
    span = (g >= c[0]) & (g <= c[-1])
    max_half_gap = np.max(np.diff(c)) / 2 + 1e-6
    assert err[span].max() <= max_half_gap
