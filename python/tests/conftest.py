import os
import sys

# Tests import the compile package from the repo's python/ dir regardless of
# where pytest is invoked from.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from hypothesis import settings

# Interpret-mode pallas is trace-heavy; keep example counts deliberate.
settings.register_profile("m22", max_examples=25, deadline=None)
settings.load_profile("m22")
