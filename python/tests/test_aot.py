"""AOT artifact emission tests: HLO text well-formedness + manifest schema."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import kernels as K
from compile.aot import smoke_fn, to_hlo_text, f32
from compile.archs import ARCHS
from compile.model import example_shapes, make_graphs
from compile.params import manifest_entries, total_size

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_emission_smoke():
    text = to_hlo_text(jax.jit(smoke_fn).lower(f32(2, 2), f32(2, 2)))
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # return_tuple=True => tuple-typed root
    assert "(f32[2,2]" in text


def test_hlo_text_quantize_block_graph():
    text = to_hlo_text(
        jax.jit(K.quantize_block).lower(
            f32(K.QUANT_BLOCK), f32(K.MAX_LEVELS - 1), f32(K.MAX_LEVELS)
        )
    )
    assert "s32[65536]" in text and "f32[65536]" in text


def test_manifest_entries_offsets_contiguous():
    for arch in ARCHS:
        specs, _, _ = make_graphs(arch)
        ents = manifest_entries(specs)
        off = 0
        for e in ents:
            assert e["offset"] == off
            assert e["size"] == int(np.prod(e["shape"]))
            off += e["size"]
        assert off == total_size(specs)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_built_artifacts_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["quant_block"] == K.QUANT_BLOCK
    assert man["max_levels"] == K.MAX_LEVELS
    for arch in ARCHS:
        assert arch in man["archs"]
        d = man["archs"][arch]["total_params"]
        init = os.path.join(ART, f"init_{arch}.f32")
        assert os.path.getsize(init) == 4 * d
        for stem in (f"train_step_{arch}", f"eval_{arch}"):
            p = os.path.join(ART, f"{stem}.hlo.txt")
            with open(p) as fh:
                assert fh.read(9) == "HloModule", p
    for stem in ("quantize_block", "moments_block", "distortion_block", "smoke"):
        assert os.path.exists(os.path.join(ART, f"{stem}.hlo.txt"))


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built",
)
def test_init_params_finite_and_scaled():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for arch in ARCHS:
        w = np.fromfile(os.path.join(ART, f"init_{arch}.f32"), dtype="<f4")
        assert np.isfinite(w).all()
        # He init: overall rms well below 1, above 0
        rms = float(np.sqrt((w**2).mean()))
        assert 1e-3 < rms < 1.0, (arch, rms)
