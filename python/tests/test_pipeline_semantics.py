"""Cross-layer semantics: the python reference of the M22 codec pipeline.

These tests pin the *contract* the Rust L3 implementation relies on:
quantize-normalize commutation, moments-driven fitting inputs, and the
distortion/quantizer consistency that eq. (13) promises.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

CHUNK = 4096


def _sorted_quantizer(rng, levels):
    c = np.sort(rng.normal(size=levels)).astype(np.float32)
    t = ((c[1:] + c[:-1]) / 2).astype(np.float32)
    c_pad = np.concatenate([c, np.full(16 - levels, c[-1], np.float32)])
    t_pad = np.concatenate([t, np.full(15 - len(t), np.float32(np.inf))])
    return t_pad, c_pad


@given(seed=st.integers(0, 2**31 - 1), scale=st.floats(0.01, 100.0))
@settings(max_examples=15)
def test_quantize_scale_commutes(seed, scale):
    """quantize(g*s, centers*s) == quantize(g, centers)*s — the property
    that lets Rust design standardized tables and scale by layer std."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=CHUNK).astype(np.float32)
    t, c = _sorted_quantizer(rng, 8)
    s = np.float32(scale)
    idx1, gh1 = K.quantize_block(jnp.asarray(g * s), jnp.asarray(t * s), jnp.asarray(c * s))
    idx2, gh2 = K.quantize_block(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    # indices identical (up to f32 rounding at bin edges — use loose check)
    mismatch = np.mean(np.asarray(idx1) != np.asarray(idx2))
    assert mismatch < 5e-3, f"index mismatch rate {mismatch}"
    same = np.asarray(idx1) == np.asarray(idx2)
    np.testing.assert_allclose(
        np.asarray(gh1)[same], np.asarray(gh2)[same] * s, rtol=2e-5, atol=1e-6
    )


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10)
def test_quantizer_centers_minimize_distortion_per_bin(seed):
    """Within each bin, replacing the center by the bin's weighted centroid
    is a fixed point (eq. 13a with M=0 over the empirical measure)."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=CHUNK).astype(np.float32)
    t, c = _sorted_quantizer(rng, 8)
    idx, _ = ref.quantize_ref(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    idx = np.asarray(idx)
    # empirical-centroid quantizer must not have higher M=0 distortion
    c_opt = c.copy()
    for b in range(8):
        mask = (idx == b) & (g != 0)
        if mask.sum() > 0:
            c_opt[b] = g[mask].mean()
    _, gh_orig = ref.quantize_ref(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c))
    _, gh_opt = ref.quantize_ref(jnp.asarray(g), jnp.asarray(t), jnp.asarray(c_opt))
    m0 = jnp.asarray([0.0], dtype=jnp.float32)
    d_orig = float(np.asarray(ref.distortion_ref(jnp.asarray(g), gh_orig, 0.0))[0])
    d_opt = float(np.asarray(ref.distortion_ref(jnp.asarray(g), gh_opt, 0.0))[0])
    assert d_opt <= d_orig + 1e-4, f"{d_opt} > {d_orig}"


def test_moments_feed_gennorm_ratio_bounds():
    """The moment ratio (E|x|)²/Ex² of any sample lies in (0, 1) — the
    domain the Rust bisection fitter assumes."""
    rng = np.random.default_rng(0)
    for _ in range(20):
        g = (rng.normal(size=CHUNK) * rng.uniform(0.001, 10)).astype(np.float32)
        g[rng.random(CHUNK) < rng.uniform(0, 0.9)] = 0.0
        if (g != 0).sum() < 2:
            continue
        s = np.asarray(K.moments_block(jnp.asarray(g)))
        n, s1, s2 = float(s[0]), float(s[1]), float(s[2])
        rho = (s1 / n) ** 2 / (s2 / n)
        assert 0.0 < rho < 1.0, rho


@pytest.mark.parametrize("m_small,m_large", [(0.0, 2.0), (2.0, 6.0)])
def test_distortion_ordering_under_tail_error(m_small, m_large):
    """Errors on tail entries cost relatively more as M grows — the paper's
    design rationale in kernel form."""
    rng = np.random.default_rng(4)
    g = rng.normal(size=CHUNK).astype(np.float32)
    tail = np.abs(g) > 1.5
    bulk = ~tail
    h_tail = g.copy()
    h_tail[tail] += 0.1
    h_bulk = g.copy()
    h_bulk[bulk] += 0.1 * np.sqrt(tail.sum() / bulk.sum())  # equal L2 energy budget

    def ratio(m):
        dt = float(np.asarray(ref.distortion_ref(jnp.asarray(g), jnp.asarray(h_tail), m))[0])
        db = float(np.asarray(ref.distortion_ref(jnp.asarray(g), jnp.asarray(h_bulk), m))[0])
        return dt / db

    assert ratio(m_large) > ratio(m_small)
