"""Hypothesis sweep of the L1 Pallas matmul kernel vs the jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref


def _rand(rng, shape, dtype):
    x = rng.normal(size=shape)
    return jnp.asarray(x.astype(dtype))


@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), np.float32)
    y = _rand(rng, (k, n), np.float32)
    out = K.pallas_matmul(x, y)
    np.testing.assert_allclose(out, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 64), (64, 256, 128)])
def test_matmul_block_aligned(shape):
    m, k, n = shape
    rng = np.random.default_rng(1)
    x = _rand(rng, (m, k), np.float32)
    y = _rand(rng, (k, n), np.float32)
    np.testing.assert_allclose(
        K.pallas_matmul(x, y), ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_matmul_dtypes(dtype):
    rng = np.random.default_rng(2)
    x = _rand(rng, (48, 32), dtype)
    y = _rand(rng, (32, 40), dtype)
    out = K.pallas_matmul(x, y)
    assert out.dtype == jnp.float32  # f32 accumulation always
    expect = ref.matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32))
    tol = 1e-4 if dtype == np.float32 else 5e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10)
def test_matmul_grad_matches_oracle(seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (33, 21), np.float32)
    y = _rand(rng, (21, 17), np.float32)

    def f(mm):
        return lambda a, b: jnp.sum(mm(a, b) ** 2)

    gx, gy = jax.grad(f(K.pallas_matmul), argnums=(0, 1))(x, y)
    rx, ry = jax.grad(f(ref.matmul_ref), argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, rx, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gy, ry, rtol=1e-3, atol=1e-3)


def test_matmul_zero_and_identity():
    eye = jnp.eye(16, dtype=jnp.float32)
    rng = np.random.default_rng(3)
    x = _rand(rng, (16, 16), np.float32)
    np.testing.assert_allclose(K.pallas_matmul(x, eye), x, rtol=1e-6, atol=1e-6)
    z = jnp.zeros((16, 16), jnp.float32)
    np.testing.assert_array_equal(K.pallas_matmul(x, z), z)
