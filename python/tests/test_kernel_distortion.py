"""Hypothesis sweep of the M-weighted distortion kernel vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

CHUNK = 4096


def _m(v):
    return jnp.asarray([v], dtype=jnp.float32)


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.sampled_from([0.0, 1.0, 2.0, 3.0, 4.0, 6.0, 9.0]),
    sparsity=st.floats(0.0, 0.9),
)
def test_distortion_matches_oracle(seed, m, sparsity):
    rng = np.random.default_rng(seed)
    g = rng.normal(size=CHUNK).astype(np.float32)
    g[rng.random(CHUNK) < sparsity] = 0.0
    ghat = (g + rng.normal(size=CHUNK, scale=0.1)).astype(np.float32)
    got = np.asarray(K.distortion_block(jnp.asarray(g), jnp.asarray(ghat), _m(m)))
    want = np.asarray(ref.distortion_ref(jnp.asarray(g), jnp.asarray(ghat), m))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-5)


def test_distortion_zero_when_equal():
    rng = np.random.default_rng(0)
    g = rng.normal(size=CHUNK).astype(np.float32)
    for m in (0.0, 2.0):
        out = np.asarray(K.distortion_block(jnp.asarray(g), jnp.asarray(g), _m(m)))
        np.testing.assert_allclose(out, 0.0, atol=1e-8)


def test_distortion_m0_is_plain_l2():
    """M = 0 must reduce to the unweighted L2 metric (TINYSCRIPT limit)."""
    rng = np.random.default_rng(1)
    g = rng.normal(size=CHUNK).astype(np.float32)
    g[::3] = 0.0
    ghat = (g + rng.normal(size=CHUNK, scale=0.2)).astype(np.float32)
    out = float(np.asarray(K.distortion_block(jnp.asarray(g), jnp.asarray(ghat), _m(0.0)))[0])
    np.testing.assert_allclose(out, float(((g - ghat) ** 2).sum()), rtol=1e-4)


def test_distortion_weights_emphasize_large_entries():
    """Same absolute error on a larger-|g| entry must cost more when M>0."""
    g = np.zeros(CHUNK, np.float32)
    g[0], g[1] = 0.5, 2.0
    h_small = g.copy(); h_small[0] += 0.1
    h_large = g.copy(); h_large[1] += 0.1
    m = _m(2.0)
    d_small = float(np.asarray(K.distortion_block(jnp.asarray(g), jnp.asarray(h_small), m))[0])
    d_large = float(np.asarray(K.distortion_block(jnp.asarray(g), jnp.asarray(h_large), m))[0])
    assert d_large > d_small
