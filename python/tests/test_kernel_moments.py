"""Hypothesis sweep of the fused moments kernel vs the oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st

from compile import kernels as K
from compile.kernels import ref

CHUNK = 4096


@given(
    seed=st.integers(0, 2**31 - 1),
    sparsity=st.floats(0.0, 0.99),
    scale=st.floats(1e-4, 1e2),
    nblocks=st.integers(1, 4),
)
def test_moments_matches_oracle(seed, sparsity, scale, nblocks):
    rng = np.random.default_rng(seed)
    n = CHUNK * nblocks
    g = (rng.normal(size=n) * scale).astype(np.float32)
    g[rng.random(n) < sparsity] = 0.0
    got = np.asarray(K.moments_block(jnp.asarray(g)))
    want = np.asarray(ref.moments_ref(jnp.asarray(g)))
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_moments_all_zero_block():
    g = jnp.zeros((CHUNK,), jnp.float32)
    got = np.asarray(K.moments_block(g))
    assert got[0] == 0.0  # nnz
    np.testing.assert_allclose(got[:5], 0.0)
    assert got[5] == 0.0  # max
    assert got[7] == 0.0  # sum log over nonzeros is empty


def test_moments_known_values():
    g = np.zeros(CHUNK, np.float32)
    g[:4] = [1.0, -2.0, 4.0, 0.5]
    got = np.asarray(K.moments_block(jnp.asarray(g)))
    a = np.abs(g[:4])
    np.testing.assert_allclose(got[0], 4.0)
    np.testing.assert_allclose(got[1], a.sum(), rtol=1e-6)
    np.testing.assert_allclose(got[2], (a**2).sum(), rtol=1e-6)
    np.testing.assert_allclose(got[3], np.sqrt(a).sum(), rtol=1e-6)
    np.testing.assert_allclose(got[4], (a**3).sum(), rtol=1e-6)
    np.testing.assert_allclose(got[5], 4.0)
    np.testing.assert_allclose(got[6], (a**4).sum(), rtol=1e-6)
    np.testing.assert_allclose(got[7], np.log(a).sum(), rtol=1e-5)


def test_moments_scale_relation():
    """abs-moment homogeneity: s1 scales linearly, s2 quadratically."""
    rng = np.random.default_rng(3)
    g = rng.normal(size=CHUNK).astype(np.float32)
    m1 = np.asarray(K.moments_block(jnp.asarray(g)))
    m2 = np.asarray(K.moments_block(jnp.asarray(2.0 * g)))
    np.testing.assert_allclose(m2[1], 2 * m1[1], rtol=1e-5)
    np.testing.assert_allclose(m2[2], 4 * m1[2], rtol=1e-5)
    np.testing.assert_allclose(m2[5], 2 * m1[5], rtol=1e-6)
