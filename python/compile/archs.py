"""The three DNN architectures of the paper (Table I), at reproduction scale.

Paper: CNN (553k params), ResNet18 (11.2M), VGG16 (33.6M) on CIFAR-10.
Here (DESIGN.md §Substitutions): CNN-S / ResNet-S / VGG-S on 12x12x3 synthetic
CIFAR-like images — same structural families (plain conv stack; residual
blocks; deep VGG-style stack whose parameter mass sits in dense layers),
scaled so interpret-lowered Pallas + XLA-CPU trains in minutes.

Every conv and dense layer is im2col + the L1 Pallas matmul kernel
(DESIGN.md §Hardware-Adaptation) so the MXU-tiled kernel is on the hot path
of fwd AND bwd of every model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import pallas_matmul
from .params import ParamSpec

IMG = 12  # input is IMG x IMG x 3
NUM_CLASSES = 10


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def im2col_3x3(x: jax.Array) -> jax.Array:
    """(B, H, W, C) -> (B*H*W, 9C) patches for a SAME 3x3 conv."""
    b, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    cols = [
        xp[:, dy : dy + h, dx : dx + w, :]
        for dy in range(3)
        for dx in range(3)
    ]
    patches = jnp.concatenate(cols, axis=-1)  # (B, H, W, 9C)
    return patches.reshape(b * h * w, 9 * c)


def conv3x3(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """SAME 3x3 conv as im2col + Pallas matmul. w: (9*Cin, Cout)."""
    bsz, h, wd, _ = x.shape
    cout = w.shape[1]
    out = pallas_matmul(im2col_3x3(x), w) + b
    return out.reshape(bsz, h, wd, cout)


def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    return pallas_matmul(x, w) + b


def maxpool2(x: jax.Array) -> jax.Array:
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def relu(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


# --------------------------------------------------------------------------
# CNN-S — plain conv stack (paper's "CNN", Table I row 1)
# --------------------------------------------------------------------------

CNN_S_SPECS = [
    ParamSpec("conv1.w", (9 * 3, 24), "conv"),
    ParamSpec("conv1.b", (24,), "bias"),
    ParamSpec("conv2.w", (9 * 24, 48), "conv"),
    ParamSpec("conv2.b", (48,), "bias"),
    ParamSpec("fc1.w", (3 * 3 * 48, 96), "dense"),
    ParamSpec("fc1.b", (96,), "bias"),
    ParamSpec("head.w", (96, NUM_CLASSES), "dense"),
    ParamSpec("head.b", (NUM_CLASSES,), "bias"),
]


def cnn_s_forward(p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    b = x.shape[0]
    h = relu(conv3x3(x, p["conv1.w"], p["conv1.b"]))
    h = maxpool2(h)  # 6x6x24
    h = relu(conv3x3(h, p["conv2.w"], p["conv2.b"]))
    h = maxpool2(h)  # 3x3x48
    h = h.reshape(b, -1)
    h = relu(dense(h, p["fc1.w"], p["fc1.b"]))
    return dense(h, p["head.w"], p["head.b"])


# --------------------------------------------------------------------------
# ResNet-S — residual blocks (paper's "ResNet18", Table I row 2)
# --------------------------------------------------------------------------

def _resblock_specs(i: int, c: int) -> list[ParamSpec]:
    return [
        ParamSpec(f"block{i}.conv_a.w", (9 * c, c), "conv"),
        ParamSpec(f"block{i}.conv_a.b", (c,), "bias"),
        ParamSpec(f"block{i}.conv_b.w", (9 * c, c), "conv"),
        ParamSpec(f"block{i}.conv_b.b", (c,), "bias"),
    ]


RESNET_S_SPECS = (
    [
        ParamSpec("stem.w", (9 * 3, 32), "conv"),
        ParamSpec("stem.b", (32,), "bias"),
    ]
    + _resblock_specs(1, 32)
    + _resblock_specs(2, 32)
    + [
        ParamSpec("fc1.w", (3 * 3 * 32, 128), "dense"),
        ParamSpec("fc1.b", (128,), "bias"),
        ParamSpec("head.w", (128, NUM_CLASSES), "dense"),
        ParamSpec("head.b", (NUM_CLASSES,), "bias"),
    ]
)


def _resblock(p: dict[str, jax.Array], i: int, x: jax.Array) -> jax.Array:
    h = relu(conv3x3(x, p[f"block{i}.conv_a.w"], p[f"block{i}.conv_a.b"]))
    h = conv3x3(h, p[f"block{i}.conv_b.w"], p[f"block{i}.conv_b.b"])
    return relu(h + x)


def resnet_s_forward(p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    b = x.shape[0]
    h = relu(conv3x3(x, p["stem.w"], p["stem.b"]))  # 12x12x32
    h = _resblock(p, 1, h)
    h = maxpool2(h)  # 6x6x32
    h = _resblock(p, 2, h)
    h = maxpool2(h)  # 3x3x32
    h = h.reshape(b, -1)
    h = relu(dense(h, p["fc1.w"], p["fc1.b"]))
    return dense(h, p["head.w"], p["head.b"])


# --------------------------------------------------------------------------
# VGG-S — deep stack, parameter mass in dense layers (paper's "VGG16")
# --------------------------------------------------------------------------

VGG_S_SPECS = [
    ParamSpec("conv1a.w", (9 * 3, 32), "conv"),
    ParamSpec("conv1a.b", (32,), "bias"),
    ParamSpec("conv1b.w", (9 * 32, 32), "conv"),
    ParamSpec("conv1b.b", (32,), "bias"),
    ParamSpec("conv2a.w", (9 * 32, 64), "conv"),
    ParamSpec("conv2a.b", (64,), "bias"),
    ParamSpec("conv2b.w", (9 * 64, 64), "conv"),
    ParamSpec("conv2b.b", (64,), "bias"),
    ParamSpec("fc1.w", (3 * 3 * 64, 160), "dense"),
    ParamSpec("fc1.b", (160,), "bias"),
    ParamSpec("fc2.w", (160, 96), "dense"),
    ParamSpec("fc2.b", (96,), "bias"),
    ParamSpec("head.w", (96, NUM_CLASSES), "dense"),
    ParamSpec("head.b", (NUM_CLASSES,), "bias"),
]


def vgg_s_forward(p: dict[str, jax.Array], x: jax.Array) -> jax.Array:
    b = x.shape[0]
    h = relu(conv3x3(x, p["conv1a.w"], p["conv1a.b"]))
    h = relu(conv3x3(h, p["conv1b.w"], p["conv1b.b"]))
    h = maxpool2(h)  # 6x6x32
    h = relu(conv3x3(h, p["conv2a.w"], p["conv2a.b"]))
    h = relu(conv3x3(h, p["conv2b.w"], p["conv2b.b"]))
    h = maxpool2(h)  # 3x3x64
    h = h.reshape(b, -1)
    h = relu(dense(h, p["fc1.w"], p["fc1.b"]))
    h = relu(dense(h, p["fc2.w"], p["fc2.b"]))
    return dense(h, p["head.w"], p["head.b"])


ARCHS = {
    "cnn_s": (CNN_S_SPECS, cnn_s_forward),
    "resnet_s": (RESNET_S_SPECS, resnet_s_forward),
    "vgg_s": (VGG_S_SPECS, vgg_s_forward),
}
