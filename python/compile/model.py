"""L2 graphs: federated train-step / eval over the flat parameter vector.

Each graph is lowered once by aot.py to HLO text and executed from the Rust
coordinator (L3) — python is never on the request path.

Graph signatures (all over a flat f32[d] parameter vector ``w``):

  train_step(w, x, y) -> (loss f32[], grads f32[d], acc f32[])
  evaluate(w, x, y)   -> (loss f32[], acc f32[])

``x`` is f32[B, IMG, IMG, 3]; ``y`` is i32[B] class labels. Loss is
categorical cross-entropy (paper Table II). The optimizer (SGD for CNN,
Adam for ResNet/VGG — Table II) lives in Rust over the flat vector.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .archs import ARCHS, IMG, NUM_CLASSES
from .params import ParamSpec, total_size, unflatten

BATCH = 32


def _loss_acc(logits: jax.Array, y: jax.Array) -> tuple[jax.Array, jax.Array]:
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32).mean()
    return nll, acc


def make_graphs(arch: str):
    """Return (specs, train_step, evaluate) for one architecture."""
    specs, forward = ARCHS[arch]

    def loss_fn(w: jax.Array, x: jax.Array, y: jax.Array):
        p = unflatten(w, specs)
        logits = forward(p, x)
        loss, acc = _loss_acc(logits, y)
        return loss, acc

    def train_step(w: jax.Array, x: jax.Array, y: jax.Array):
        (loss, acc), grads = jax.value_and_grad(loss_fn, has_aux=True)(w, x, y)
        return loss, grads, acc

    def evaluate(w: jax.Array, x: jax.Array, y: jax.Array):
        loss, acc = loss_fn(w, x, y)
        return loss, acc

    return specs, train_step, evaluate


def example_shapes(arch: str, batch: int = BATCH):
    """ShapeDtypeStructs for lowering."""
    specs, _ = ARCHS[arch]
    d = total_size(specs)
    return (
        jax.ShapeDtypeStruct((d,), jnp.float32),
        jax.ShapeDtypeStruct((batch, IMG, IMG, 3), jnp.float32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


def arch_summary(arch: str) -> dict:
    """Table-I style row: layer count + param split by kind."""
    specs, _ = ARCHS[arch]
    conv = sum(s.size for s in specs if s.kind == "conv")
    den = sum(s.size for s in specs if s.kind == "dense")
    bias = sum(s.size for s in specs if s.kind == "bias")
    return {
        "arch": arch,
        "tensors": len(specs),
        "total_params": total_size(specs),
        "conv_params": conv,
        "dense_params": den,
        "bias_params": bias,
    }
