"""L1 Pallas kernel: tiled matmul — the MXU workhorse of every conv/dense layer.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper trains its
DNNs on GPUs; here every conv is im2col + this kernel, tiled for the TPU MXU:

  * grid (M/bm, N/bn, K/bk), K innermost so the (bm, bn) output block stays
    resident in VMEM across the K loop (accumulate-in-place, one HBM write).
  * blocks default to 128x128x128 — MXU-aligned; callers pad to multiples
    via `pad_matmul` (pallas_matmul does it automatically).
  * f32 accumulation via `preferred_element_type` regardless of input dtype
    (bf16 inputs hit the MXU's native bf16 path on real hardware).

Kernels are lowered with interpret=True — CPU PJRT cannot execute Mosaic
custom-calls; the interpreter traces to plain HLO, which XLA-CPU runs natively.

jax.grad does not flow through pallas_call, so `pallas_matmul` carries a
custom_vjp whose backward passes are themselves pallas matmuls
(dA = dC @ B^T, dB = A^T @ dC).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile. §Perf opt L2-1: fatter tiles than the classic 128³ —
# (512, 512, 256) stays ≈ (512·512 + 512·256 + 512·256)·4B ≈ 2 MiB VMEM
# (≪ 16 MiB, double-buffering headroom ≥ 6×) while cutting the grid-step
# count ~8×. Interpret-lowered grids become XLA while-loop iterations with
# dynamic slices, so fewer/fatter steps directly cut train-step latency
# (ResNet-S fwd+bwd: 732 ms → see EXPERIMENTS.md §Perf).
DEFAULT_BLOCK = (512, 512, 256)


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (bm, bn) output tile; accumulates over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _ceil_to(v: int, m: int) -> int:
    return -(-v // m) * m


def _pick_block(m: int, k: int, n: int, block) -> tuple[int, int, int]:
    """Shrink default blocks for small operands so padding never dominates.

    Keeps the lane dimension a multiple of 8 where possible — the VPU/MXU
    sublane granularity — while capping at the requested block."""
    bm, bk, bn = block

    def fit(dim: int, b: int) -> int:
        if dim >= b:
            return b
        return max(8, _ceil_to(dim, 8))

    return fit(m, bm), fit(k, bk), fit(n, bn)


def matmul_padded(x: jax.Array, y: jax.Array, block=DEFAULT_BLOCK) -> jax.Array:
    """Pallas matmul over operands already padded to block multiples."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bk, bn = block
    assert m % bm == 0 and k % bk == 0 and n % bn == 0, (x.shape, y.shape, block)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _matmul_raw(x: jax.Array, y: jax.Array, block=DEFAULT_BLOCK) -> jax.Array:
    """Pad-to-block, run the kernel, slice back."""
    m, k = x.shape
    _, n = y.shape
    bm, bk, bn = _pick_block(m, k, n, block)
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    out = matmul_padded(xp, yp, (bm, bk, bn))
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def pallas_matmul(x: jax.Array, y: jax.Array, block=DEFAULT_BLOCK) -> jax.Array:
    """Differentiable tiled Pallas matmul: ``x @ y`` with f32 accumulation."""
    return _matmul_raw(x, y, block)


def _mm_fwd(x, y, block):
    return _matmul_raw(x, y, block), (x, y)


def _mm_bwd(block, res, g):
    x, y = res
    # Backward matmuls reuse the same MXU tiling.
    dx = _matmul_raw(g, y.T, block).astype(x.dtype)
    dy = _matmul_raw(x.T, g, block).astype(y.dtype)
    return dx, dy


pallas_matmul.defvjp(_mm_fwd, _mm_bwd)
