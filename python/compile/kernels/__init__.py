"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""

from .matmul import pallas_matmul, matmul_padded, DEFAULT_BLOCK
from .quantize import quantize_block, MAX_LEVELS, BLOCK as QUANT_BLOCK
from .moments import moments_block, N_STATS
from .distortion import distortion_block

__all__ = [
    "pallas_matmul",
    "matmul_padded",
    "DEFAULT_BLOCK",
    "quantize_block",
    "MAX_LEVELS",
    "QUANT_BLOCK",
    "moments_block",
    "N_STATS",
    "distortion_block",
]
