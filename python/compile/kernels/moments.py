"""L1 Pallas kernel: fused block moment reduction for distribution fitting.

The 2-dof fitters (GenNorm beta, d-Weibull c — paper Sec. III-A) need absolute
moments of the *nonzero* (surviving topK) gradient entries. A naive
implementation makes one HBM pass per statistic; here all eight come out of a
single VMEM residency (DESIGN.md §Hardware-Adaptation):

  out[0] = nnz            out[1] = sum |g|         out[2] = sum g^2
  out[3] = sum sqrt(|g|)  out[4] = sum |g|^3       out[5] = max |g|
  out[6] = sum g^4        out[7] = sum log|g| (over nonzeros)

Partial sums accumulate across the 1-D grid into the (8,) output block, which
stays resident (same index-map block for every grid step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_STATS = 8
CHUNK = 4096


def _moments_kernel(g_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    a = jnp.abs(g)
    nz = a > 0.0
    nzf = nz.astype(jnp.float32)
    # log over nonzeros only; zeros contribute 0 via the mask.
    safe = jnp.where(nz, a, 1.0)
    stats = jnp.stack(
        [
            jnp.sum(nzf),
            jnp.sum(a),
            jnp.sum(a * a),
            jnp.sum(jnp.sqrt(a)),
            jnp.sum(a * a * a),
            jnp.max(a),
            jnp.sum(a * a * a * a),
            jnp.sum(jnp.log(safe)),
        ]
    )
    prev = o_ref[...]
    # All-sum accumulate except the max slot (index 5).
    acc = prev + stats
    acc = acc.at[5].set(jnp.maximum(prev[5], stats[5]))
    o_ref[...] = acc


def moments_block(g: jax.Array) -> jax.Array:
    """Fused moments of a 1-D block. g: (B,) f32, B multiple of CHUNK.

    Returns (8,) f32 — see module docstring for the layout."""
    (b,) = g.shape
    assert b % CHUNK == 0, b
    grid = (b // CHUNK,)
    return pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((CHUNK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((N_STATS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((N_STATS,), jnp.float32),
        interpret=True,
    )(g)
