"""L1 Pallas kernel: scalar-quantizer assignment (the M22 codec hot path).

Given a (sparsified, per-layer-normalized) gradient block and a quantizer
(centers + thresholds from the Rust LBG designer, eq. 13 of the paper), emit

  * ``idx``  — the quantization-bin index of every entry, and
  * ``ghat`` — the dequantized reconstruction (zeros stay exactly zero, so a
    dense reconstructed block comes straight out; the Rust codec bit-packs
    ``idx`` only at nonzero positions).

Hardware adaptation: the reference implementation does a per-element
searchsorted (gather-heavy, fine on GPU). On TPU we make it branch-free and
lane-parallel: broadcast all ``L-1 <= 15`` thresholds across lanes and count
``g >= t_i`` masks — one VPU pass; the dequantize gather becomes a sum of
``centers_i * (idx == i)`` masks. Quantizers with fewer than MAX_LEVELS
levels are padded: thresholds with +inf (never crossed), centers by
repeating the last center (never selected).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Fixed codec geometry: rate R in {1..4} bits => at most 16 centers.
MAX_LEVELS = 16
# One VMEM-resident chunk of the 64k element block: 4096 f32 = 16 KiB in,
# 16 KiB idx + 16 KiB ghat out.
CHUNK = 4096
BLOCK = 65536


def _quantize_kernel(g_ref, t_ref, c_ref, idx_ref, ghat_ref):
    g = g_ref[...]  # (CHUNK,)
    t = t_ref[...]  # (MAX_LEVELS - 1,) padded with +inf
    c = c_ref[...]  # (MAX_LEVELS,)   padded by repeating last center
    # Branch-free bin assignment: idx_j = #thresholds <= g_j.
    ge = (g[:, None] >= t[None, :]).astype(jnp.int32)  # (CHUNK, 15)
    idx = jnp.sum(ge, axis=1)  # in [0, MAX_LEVELS)
    # Gather-free dequantize: one-hot mask contraction against centers.
    onehot = (idx[:, None] == jnp.arange(MAX_LEVELS)[None, :]).astype(g.dtype)
    ghat = onehot @ c
    # Sparsified zeros survive exactly (coded by RLE, not by the quantizer).
    nz = g != 0.0
    idx_ref[...] = jnp.where(nz, idx, 0).astype(jnp.int32)
    ghat_ref[...] = jnp.where(nz, ghat, 0.0).astype(ghat_ref.dtype)


def quantize_block(g: jax.Array, thresholds: jax.Array, centers: jax.Array):
    """Quantize a 1-D block. g: (B,) f32, thresholds: (15,), centers: (16,).

    Returns (idx i32 (B,), ghat f32 (B,)). B must be a multiple of CHUNK."""
    (b,) = g.shape
    assert b % CHUNK == 0, b
    assert thresholds.shape == (MAX_LEVELS - 1,), thresholds.shape
    assert centers.shape == (MAX_LEVELS,), centers.shape
    grid = (b // CHUNK,)
    return pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((MAX_LEVELS - 1,), lambda i: (0,)),
            pl.BlockSpec((MAX_LEVELS,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.int32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(g, thresholds, centers)
