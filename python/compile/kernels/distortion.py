"""L1 Pallas kernel: M-magnitude-weighted L2 distortion (paper eq. 12/13).

    d_M(g, ghat) = (1/B) * sum_j |g_j|^M * (g_j - ghat_j)^2

Note on the paper: eq. (12) writes ``|g_j|^M || g_j - ghat_j ||_2`` but the
LBG centroid rule it derives in eq. (13) — c = E[g^{M+1}] / E[g^M] — is the
minimizer of the *squared*-error form above, so the squared form is what the
system actually optimizes (and what we implement, in both this kernel and the
Rust quantizer designer).

M arrives as a traced (1,) array so one compiled artifact serves every M.
``0^0`` is defined as 1 (the M=0 case must degrade exactly to plain L2,
recovering TINYSCRIPT — paper Sec. V-A).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 4096


def _distortion_kernel(g_ref, h_ref, m_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    g = g_ref[...]
    h = h_ref[...]
    m = m_ref[0]
    a = jnp.abs(g)
    # weight = |g|^M with 0^0 := 1 (zero-weight otherwise for zero entries).
    w = jnp.where(a > 0.0, jnp.exp(m * jnp.log(jnp.where(a > 0.0, a, 1.0))),
                  jnp.where(m == 0.0, 1.0, 0.0))
    e = g - h
    o_ref[...] += jnp.sum(w * e * e)[None]


def distortion_block(g: jax.Array, ghat: jax.Array, m: jax.Array) -> jax.Array:
    """Weighted distortion *sum* over a 1-D block (caller divides by count).

    g, ghat: (B,) f32 with B a multiple of CHUNK; m: (1,) f32. Returns (1,)."""
    (b,) = g.shape
    assert ghat.shape == (b,), (g.shape, ghat.shape)
    assert m.shape == (1,), m.shape
    grid = (b // CHUNK,)
    return pl.pallas_call(
        _distortion_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((CHUNK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(g, ghat, m)
