"""Pure-jnp oracles for every L1 Pallas kernel.

These are the CORE correctness signal: python/tests sweep shapes/dtypes with
hypothesis and assert_allclose each kernel against its oracle here.
"""

from __future__ import annotations

import jax.numpy as jnp

from .quantize import MAX_LEVELS


def matmul_ref(x, y):
    """Oracle for kernels.matmul.pallas_matmul."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def quantize_ref(g, thresholds, centers):
    """Oracle for kernels.quantize.quantize_block (searchsorted semantics)."""
    idx = jnp.searchsorted(thresholds, g, side="right").astype(jnp.int32)
    ghat = centers[idx]
    nz = g != 0.0
    idx = jnp.where(nz, idx, 0).astype(jnp.int32)
    ghat = jnp.where(nz, ghat, 0.0)
    return idx, ghat


def moments_ref(g):
    """Oracle for kernels.moments.moments_block."""
    a = jnp.abs(g)
    nz = a > 0.0
    safe = jnp.where(nz, a, 1.0)
    return jnp.stack(
        [
            jnp.sum(nz.astype(jnp.float32)),
            jnp.sum(a),
            jnp.sum(a * a),
            jnp.sum(jnp.sqrt(a)),
            jnp.sum(a**3),
            jnp.max(a),
            jnp.sum(a**4),
            jnp.sum(jnp.log(safe)),
        ]
    )


def distortion_ref(g, ghat, m):
    """Oracle for kernels.distortion.distortion_block (sum, not mean)."""
    a = jnp.abs(g)
    w = jnp.where(a > 0.0, a ** m, jnp.where(m == 0.0, 1.0, 0.0))
    e = g - ghat
    return jnp.sum(w * e * e)[None]


__all__ = ["matmul_ref", "quantize_ref", "moments_ref", "distortion_ref",
           "MAX_LEVELS"]
