"""Flat-parameter plumbing for the L2 models.

The Rust coordinator owns model state as ONE flat f32 vector per model (the
uplink payload of the paper is exactly this vector's update). Each
architecture publishes a static ``ParamSpec`` table (name, shape, kind);
offsets are cumulative, so L2 unflattening is static slicing (no dynamic
shapes in the lowered HLO) and L3 sees the same layout via the manifest.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ParamSpec:
    """One tensor in the flat layout."""

    name: str
    shape: tuple[int, ...]
    kind: str  # "conv" | "dense" | "bias"

    @property
    def size(self) -> int:
        return math.prod(self.shape)


def offsets(specs: list[ParamSpec]) -> list[int]:
    offs, o = [], 0
    for s in specs:
        offs.append(o)
        o += s.size
    return offs


def total_size(specs: list[ParamSpec]) -> int:
    return sum(s.size for s in specs)


def unflatten(w: jax.Array, specs: list[ParamSpec]) -> dict[str, jax.Array]:
    """Static slicing of the flat vector into named tensors."""
    out = {}
    for s, o in zip(specs, offsets(specs)):
        out[s.name] = jax.lax.slice(w, (o,), (o + s.size,)).reshape(s.shape)
    return out


def flatten(params: dict[str, jax.Array], specs: list[ParamSpec]) -> jax.Array:
    return jnp.concatenate([params[s.name].reshape(-1) for s in specs])


def init_params(specs: list[ParamSpec], seed: int) -> jax.Array:
    """He-normal init for weights, zeros for biases, as one flat vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for s in specs:
        key, sub = jax.random.split(key)
        if s.kind == "bias":
            chunks.append(jnp.zeros((s.size,), jnp.float32))
        else:
            fan_in = math.prod(s.shape[:-1])
            std = math.sqrt(2.0 / max(fan_in, 1))
            chunks.append(
                (jax.random.normal(sub, (s.size,), jnp.float32) * std)
            )
    return jnp.concatenate(chunks)


def manifest_entries(specs: list[ParamSpec]) -> list[dict]:
    """JSON-ready layout table for the Rust side."""
    return [
        {
            "name": s.name,
            "shape": list(s.shape),
            "kind": s.kind,
            "offset": o,
            "size": s.size,
        }
        for s, o in zip(specs, offsets(specs))
    ]
