"""AOT compile path: lower every L2 graph ONCE to HLO text + write manifest.

Run as ``python -m compile.aot --out-dir ../artifacts`` (see Makefile
``artifacts`` target). Python never runs after this: the Rust coordinator
loads the HLO text via PJRT (`HloModuleProto::from_text_file`).

Interchange is HLO *text*, not ``lowered.compile()``/``.serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 (the version the published ``xla`` 0.1.6 crate binds)
rejects with ``proto.id() <= INT_MAX``. The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs in --out-dir:
  train_step_<arch>.hlo.txt   (w, x, y) -> (loss, grads, acc)
  eval_<arch>.hlo.txt         (w, x, y) -> (loss, acc)
  quantize_block.hlo.txt      (g[B], t[15], c[16]) -> (idx i32[B], ghat[B])
  moments_block.hlo.txt       (g[B]) -> (8,) fused stats
  distortion_block.hlo.txt    (g[B], ghat[B], m[1]) -> (1,)
  smoke.hlo.txt               (x[2,2], y[2,2]) -> (x@y + 2,)   [runtime tests]
  init_<arch>.f32             raw little-endian f32 initial flat params
  manifest.json               shapes + per-tensor layout for the Rust side
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import kernels as K
from .model import BATCH, arch_summary, example_shapes, make_graphs
from .archs import ARCHS, IMG, NUM_CLASSES
from .params import init_params, manifest_entries

QBLOCK = K.QUANT_BLOCK  # 65536


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to(path: str, fn, *shapes) -> None:
    text = to_hlo_text(jax.jit(fn).lower(*shapes))
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text)} chars)")


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def smoke_fn(x, y):
    return (jnp.matmul(x, y) + 2.0,)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--init-seed", type=int, default=17)
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)

    manifest: dict = {
        "batch": BATCH,
        "img": IMG,
        "num_classes": NUM_CLASSES,
        "quant_block": QBLOCK,
        "max_levels": K.MAX_LEVELS,
        "n_stats": K.N_STATS,
        "init_seed": args.init_seed,
        "archs": {},
    }

    for arch in ARCHS:
        specs, train_step, evaluate = make_graphs(arch)
        shapes = example_shapes(arch)
        print(f"[{arch}] lowering train/eval (d={shapes[0].shape[0]})")
        lower_to(os.path.join(out, f"train_step_{arch}.hlo.txt"),
                 train_step, *shapes)
        lower_to(os.path.join(out, f"eval_{arch}.hlo.txt"), evaluate, *shapes)

        w0 = init_params(specs, args.init_seed)
        init_path = os.path.join(out, f"init_{arch}.f32")
        with open(init_path, "wb") as f:
            f.write(bytes(memoryview(jax.device_get(w0))))
        print(f"  wrote {init_path} ({w0.size} f32)")

        manifest["archs"][arch] = dict(
            arch_summary(arch), params=manifest_entries(specs)
        )

    print("[codec] lowering quantize/moments/distortion blocks")
    lower_to(
        os.path.join(out, "quantize_block.hlo.txt"),
        K.quantize_block,
        f32(QBLOCK), f32(K.MAX_LEVELS - 1), f32(K.MAX_LEVELS),
    )
    lower_to(os.path.join(out, "moments_block.hlo.txt"),
             K.moments_block, f32(QBLOCK))
    lower_to(
        os.path.join(out, "distortion_block.hlo.txt"),
        K.distortion_block,
        f32(QBLOCK), f32(QBLOCK), f32(1),
    )
    lower_to(os.path.join(out, "smoke.hlo.txt"), smoke_fn, f32(2, 2), f32(2, 2))

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote {out}/manifest.json")


if __name__ == "__main__":
    main()
