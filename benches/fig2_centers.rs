//! Bench target: regenerate Fig. 2 (quantization centers/thresholds vs M)
//! and time the LBG designer. `cargo bench --bench fig2_centers`

use m22::quantizer::design;
use m22::stats::{GenNorm, Weibull2};
use m22::util::bench::Bencher;

fn main() {
    // the figure data itself
    let csv = m22::figures::fig2();
    let rows = csv.lines().count() - 1;
    println!("fig2: {rows} (m, kind, index, value) rows");
    // show the headline trend: innermost positive center vs M
    for m in [0.0, 2.0, 4.0, 8.0] {
        let q = design(&GenNorm::standardized(1.0), m, 8);
        println!("  M={m}: inner center {:.4}, outer {:.4}", q.centers[4], q.centers[7]);
    }

    // perf: single LBG design (the table-prewarm unit of work)
    let b = Bencher::default();
    b.run("lbg design gennorm(1.0) M=2 L=8", || design(&GenNorm::standardized(1.0), 2.0, 8));
    b.run("lbg design gennorm(0.6) M=9 L=16", || design(&GenNorm::standardized(0.6), 9.0, 16));
    b.run("lbg design weibull(0.8) M=4 L=8", || design(&Weibull2::standardized(0.8), 4.0, 8));
}
