//! Bench target: regenerate Fig. 5 (ResNet non-uniform schemes; VGG budget
//! sweep) at reduced scale. `cargo bench --bench fig5_models`;
//! paper scale: `repro fig5a --full` / `repro fig5b --full`.

use std::path::PathBuf;
use std::time::Instant;

use m22::figures::{fig5a, fig5b, FigScale};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("fig5 skipped (artifacts not built)");
        return;
    }
    let rt = m22::runtime::spawn(dir).expect("runtime");
    let mut scale = FigScale::smoke();
    scale.rounds = 3;
    let t0 = Instant::now();
    let (ra, _) = fig5a(&rt, scale).expect("fig5a");
    println!("fig5a (resnet_s): {} series in {:.1}s", ra.series_names().len(), t0.elapsed().as_secs_f64());
    let t1 = Instant::now();
    let (rb, _) = fig5b(&rt, scale).expect("fig5b");
    println!("fig5b (vgg_s): {} series in {:.1}s", rb.series_names().len(), t1.elapsed().as_secs_f64());
}
