//! Bench target: regenerate Fig. 1 (distribution fitting under topK) and
//! time the fitting path. `cargo bench --bench fig1_fitting`

use std::path::PathBuf;

use m22::figures::{fig1, FigScale};
use m22::stats::fitting::{fit_gennorm, fit_weibull2, Moments};
use m22::stats::{Distribution, GenNorm};
use m22::util::bench::Bencher;
use m22::util::rng::Rng;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = m22::runtime::spawn(dir).expect("runtime");
        let csv = fig1(&rt, FigScale::smoke()).expect("fig1");
        println!("fig1: {} rows (histogram + 4 fitted pdfs, 2 panels)", csv.lines().count());
    } else {
        eprintln!("fig1 skipped (artifacts not built)");
    }

    // perf: moment fitting on a 41k-entry layer (CNN fc1-sized)
    let truth = GenNorm::new(0.01, 0.8);
    let mut rng = Rng::new(3);
    let layer: Vec<f32> = (0..41_472).map(|_| truth.sample(&mut rng) as f32).collect();
    let b = Bencher::default().throughput(41_472.0);
    b.run("moments 41k layer", || Moments::from_nonzeros(&layer).unwrap());
    let m = Moments::from_nonzeros(&layer).unwrap();
    let b2 = Bencher::default();
    b2.run("fit gennorm", || fit_gennorm(&m));
    b2.run("fit weibull", || fit_weibull2(&m));
}
