//! Perf benches: every L3 hot path + the PJRT execution boundary.
//! `cargo bench --bench perf_hotpath` — the numbers behind
//! EXPERIMENTS.md §Perf (before/after table).

use std::path::PathBuf;
use std::sync::Arc;

use m22::compress::m22::{M22, M22Config};
use m22::compress::rle::{encode_positions, position_bits};
use m22::compress::topk::topk;
use m22::compress::bitpack::pack_indices;
use m22::compress::{BlockCodec, Budget, Compressor, CpuCodec};
use m22::quantizer::{design, Family, QuantizerTables};
use m22::stats::fitting::Moments;
use m22::stats::{Distribution, GenNorm};
use m22::train::Manifest;
use m22::util::bench::Bencher;
use m22::util::rng::Rng;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let dist = GenNorm::new(0.01, 0.8);
    let mut rng = Rng::new(seed);
    (0..d).map(|_| dist.sample(&mut rng) as f32).collect()
}

fn main() {
    println!("== L3 hot paths (VGG-S-sized gradient d = 174314) ==");
    let d = 174_314usize;
    let g = grad(d, 1);
    let k = (0.6 * d as f64) as usize;

    let b = Bencher::default().throughput(d as f64);
    b.run("topk quickselect 0.6d", || topk(&g, k).1.len());

    let (sparse, positions) = topk(&g, k);
    let b = Bencher::default().throughput(k as f64);
    b.run("rle gap-encode positions", || encode_positions(&positions).len());
    b.run("rle position_bits (analytic)", || position_bits(&positions));

    let idx: Vec<u32> = (0..k as u32).map(|i| i % 8).collect();
    b.run("bitpack 3-bit indices", || pack_indices(&idx, 3).len());

    let b1 = Bencher::default().throughput(d as f64);
    b1.run("moments (rust) full grad", || Moments::from_nonzeros(&sparse).unwrap());

    let q = design(&GenNorm::standardized(0.8), 2.0, 8);
    let (t, c) = q.padded_f32(16);
    b1.run("cpu quantize full grad", || CpuCodec.quantize(&sparse, &t, &c).unwrap().0.len());

    // end-to-end compress/decompress (CPU codec path)
    let spec_layout = {
        // VGG-shaped spec straight from the manifest if available, else synthetic
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok().and_then(|m| m.model("vgg_s").ok().cloned())
    };
    if let Some(spec) = &spec_layout {
        let tables = Arc::new(QuantizerTables::new());
        let budget = Budget::paper_point(spec.d(), 2);
        let gg = grad(spec.d(), 2);
        let mut comp = M22::new(
            M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k: budget.k_ref, min_fit: 512 },
            Arc::new(CpuCodec),
            tables,
        );
        // warm the quantizer table so we time the request path, not design
        let _ = comp.compress(&gg, spec).unwrap();
        let b2 = Bencher::default().throughput(spec.d() as f64);
        b2.run("m22 compress e2e (vgg_s, cpu codec)", || {
            comp.compress(&gg, spec).unwrap().payload.len()
        });
        let payload = comp.compress(&gg, spec).unwrap().payload;
        b2.run("m22 decompress e2e (vgg_s)", || {
            comp.decompress(&payload, spec).unwrap().len()
        });
    }

    println!("\n== PJRT boundary (needs artifacts) ==");
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = m22::runtime::spawn(dir.clone()).expect("runtime");
        let man = Manifest::load(&dir).unwrap();
        let ds = m22::data::Dataset::generate(Default::default());
        for arch in ["cnn_s", "resnet_s", "vgg_s"] {
            let w = man.load_init(&dir, arch).unwrap();
            let batch = ds.batch(&ds.train, 0, man.batch);
            let b3 = Bencher { warmup_iters: 2, samples: 8, iters_per_sample: 1, items_per_iter: None };
            b3.run(&format!("pjrt train_step {arch}"), || {
                rt.train_step(arch, &w, &batch.x, &batch.y).unwrap().loss
            });
        }
        // HLO codec block vs CPU codec block
        let blk = grad(65_536, 3);
        let b4 = Bencher::default().throughput(65_536.0);
        b4.run("hlo quantize 64k block", || rt.quantize(&blk, &t, &c).unwrap().0.len());
        b4.run("cpu quantize 64k block", || CpuCodec.quantize(&blk, &t, &c).unwrap().0.len());
        b4.run("hlo moments 64k block", || rt.moments(&blk).unwrap()[0]);
        b4.run("cpu moments 64k block", || CpuCodec.moments(&blk).unwrap()[0]);
    } else {
        eprintln!("pjrt benches skipped (artifacts not built)");
    }
}
