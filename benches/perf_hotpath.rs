//! Perf benches: every L3 hot path + the PJRT execution boundary.
//! `cargo bench --bench perf_hotpath` — the numbers behind
//! EXPERIMENTS.md §Perf (before/after table).
//!
//! CI mode (the `bench-smoke` lane): `BENCH_QUICK=1` switches every
//! bencher to the quick sampling profile and `BENCH_JSON=path` writes the
//! machine-readable `BENCH_ci.json` artifact that
//! `python/tools/fill_experiments.py` folds into the EXPERIMENTS.md
//! wall-clock cells.

use std::path::PathBuf;
use std::sync::Arc;

use m22::compress::bitpack::pack_indices;
use m22::compress::kernels::{self, Kernels};
use m22::compress::m22::{M22, M22Config};
use m22::compress::rle::{encode_positions, position_bits};
use m22::compress::topk::topk;
use m22::compress::{
    encode_once, BlockCodec, Budget, CpuCodec, Decoder, EncodeCtx, Encoder, NoCompression,
};
use m22::config::{ClusterConfig, ExperimentConfig, PsMode, ScenarioSpec, Scheme, ServerConfig};
use m22::fedserve::aggregate::{accumulate_sharded, aggregate_serial, aggregate_sharded};
use m22::fedserve::sim::sim_spec;
use m22::fedserve::{
    simulate_fleet, simulate_with, wire, AdaptiveController, ChannelTransport, FedServer,
    LruTableCache, TransportMode,
};
use m22::quantizer::{design, Family, QuantizerTables};
use m22::stats::fitting::Moments;
use m22::stats::{Distribution, GenNorm};
use m22::train::Manifest;
use m22::util::bench::{quick_mode, BenchLog, Bencher};
use m22::util::rng::Rng;

fn grad(d: usize, seed: u64) -> Vec<f32> {
    let dist = GenNorm::new(0.01, 0.8);
    let mut rng = Rng::new(seed);
    (0..d).map(|_| dist.sample(&mut rng) as f32).collect()
}

fn main() {
    let mut log = BenchLog::new();

    println!("== L3 hot paths (VGG-S-sized gradient d = 174314) ==");
    let d = 174_314usize;
    let g = grad(d, 1);
    let k = (0.6 * d as f64) as usize;

    let b = Bencher::from_env().throughput(d as f64);
    log.push(b.run("topk quickselect 0.6d", || topk(&g, k).1.len()));

    let (sparse, positions) = topk(&g, k);
    let b = Bencher::from_env().throughput(k as f64);
    log.push(b.run("rle gap-encode positions", || encode_positions(&positions).len()));
    log.push(b.run("rle position_bits (analytic)", || position_bits(&positions)));

    let idx: Vec<u32> = (0..k as u32).map(|i| i % 8).collect();
    log.push(b.run("bitpack 3-bit indices", || pack_indices(&idx, 3).len()));

    let b1 = Bencher::from_env().throughput(d as f64);
    log.push(b1.run("moments (rust) full grad", || Moments::from_nonzeros(&sparse).unwrap()));

    let q = design(&GenNorm::standardized(0.8), 2.0, 8);
    let (t, c) = q.padded_f32(16);
    log.push(b1.run("cpu quantize full grad", || {
        CpuCodec::new().quantize(&sparse, &t, &c).unwrap().0.len()
    }));

    // --- the PS hot loop: decode + eq.-(7) reduce, before vs after --------
    //
    // "dense" is the pre-split path: every payload decoded to a dense
    // Vec<f32> (one d-sized allocation per client per round), then the
    // sharded dense reduce. "fused" is the Encoder/Decoder-split path the
    // server now runs: survivors stream straight into the shard
    // accumulators — zero dense ĝ materializations, allocations independent
    // of client count.
    {
        let d = 65_536usize;
        let spec = sim_spec(d);
        let budget = Budget::paper_point(d, 2);
        let tables = Arc::new(QuantizerTables::new());
        let comp = M22::new(
            M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k: budget.k_ref, min_fit: 512 },
            Arc::new(CpuCodec::new()),
            tables,
        );
        for n_clients in [4usize, 16, 64] {
            let payloads: Vec<Vec<u8>> = (0..n_clients)
                .map(|i| encode_once(&comp, &grad(d, 100 + i as u64), &spec).unwrap().0)
                .collect();
            let slices: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            let bps = Bencher::from_env().throughput((n_clients * d) as f64);
            log.push(bps.run(&format!("ps dense decode+reduce  (n={n_clients}, 4 shards)"), || {
                let decoded: Vec<Vec<f32>> = slices
                    .iter()
                    .map(|p| comp.decode_dense(p, &spec).unwrap())
                    .collect();
                aggregate_sharded(&decoded, d, 4).len()
            }));
            let mut acc = vec![0.0f32; d];
            log.push(bps.run(&format!("ps fused  decode+reduce (n={n_clients}, 4 shards)"), || {
                acc.clear();
                acc.resize(d, 0.0);
                accumulate_sharded(&comp, &slices, &spec, 4, &mut acc).unwrap();
                acc.len()
            }));
            log.push(bps.run(&format!("ps fused  decode+reduce (n={n_clients}, serial)"), || {
                acc.clear();
                acc.resize(d, 0.0);
                for p in &slices {
                    comp.decode_accumulate(p, &spec, 1.0, &mut acc).unwrap();
                }
                acc.len()
            }));
            // sanity: the two paths agree bit-exactly
            let decoded: Vec<Vec<f32>> =
                slices.iter().map(|p| comp.decode_dense(p, &spec).unwrap()).collect();
            let dense = aggregate_serial(&decoded, d);
            acc.clear();
            acc.resize(d, 0.0);
            accumulate_sharded(&comp, &slices, &spec, 4, &mut acc).unwrap();
            assert!(dense.iter().zip(&acc).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    // --- kernel backends: scalar vs SIMD on the four codec hot loops -----
    //
    // The `compress::kernels` dispatch isolated: one quantizer block at
    // the 8-level / 3-bit paper geometry, identical inputs per backend.
    // Rows exist per available backend (`scalar` always; `avx2` on
    // x86-64 hosts with AVX2), so the EXPERIMENTS.md §kernels table can
    // quote the speedup directly. The fused-reduce rows time
    // `scatter_add` over a 0.6d topK survivor stream — the per-client
    // inner loop of the eq.-(7) reduce.
    println!("\n== codec kernels (scalar vs SIMD) ==");
    {
        let mut backends: Vec<&'static dyn Kernels> = vec![kernels::scalar_kernels()];
        match kernels::simd_kernels() {
            Some(ks) => backends.push(ks),
            None => eprintln!("kernel SIMD rows skipped (no SIMD backend on this host)"),
        }
        let q = design(&GenNorm::standardized(0.8), 2.0, 8);
        let blk = q.padded_block(1.0);
        let bits = 3u32; // 8 levels -> 3-bit codes
        for d in [65_536usize, 1_000_000] {
            let g = grad(d, 21);
            let (survivors, positions) = topk(&g, (0.6 * d as f64) as usize);
            let values: Vec<f32> = positions.iter().map(|&p| survivors[p as usize]).collect();
            let mut idx = vec![0u32; d];
            let mut ghat = vec![0.0f32; d];
            let mut bytes: Vec<u8> = Vec::new();
            let mut codes = vec![0u32; d];
            let mut acc = vec![0.0f32; d];
            for &ks in &backends {
                let name = ks.name();
                let b = Bencher::from_env().throughput(d as f64);
                log.push(b.run(&format!("kernel quantize ({name}, d={d})"), || {
                    ks.quantize_block(&g, &blk.thresholds, &blk.centers, &mut idx, &mut ghat);
                    idx.len()
                }));
                log.push(b.run(&format!("kernel pack ({name}, d={d})"), || {
                    bytes.clear();
                    ks.pack(&idx, bits, &mut bytes);
                    bytes.len()
                }));
                log.push(b.run(&format!("kernel unpack ({name}, d={d})"), || {
                    assert!(ks.unpack(&bytes, 0, bits, &mut codes));
                    codes.len()
                }));
                let bk = Bencher::from_env().throughput(positions.len() as f64);
                log.push(bk.run(&format!("kernel fused reduce ({name}, d={d})"), || {
                    ks.scatter_add(&positions, &values, 0.5, &mut acc);
                    acc.len()
                }));
            }
            // sanity (untimed): both backends agree on these exact inputs
            if let [sc, sd] = backends[..] {
                let mut idx2 = vec![0u32; d];
                let mut ghat2 = vec![0.0f32; d];
                sc.quantize_block(&g, &blk.thresholds, &blk.centers, &mut idx, &mut ghat);
                sd.quantize_block(&g, &blk.thresholds, &blk.centers, &mut idx2, &mut ghat2);
                assert_eq!(idx, idx2, "kernel bench: quantize parity broke at d={d}");
                let mut b1 = Vec::new();
                let mut b2 = Vec::new();
                sc.pack(&idx, bits, &mut b1);
                sd.pack(&idx, bits, &mut b2);
                assert_eq!(b1, b2, "kernel bench: pack parity broke at d={d}");
            }
        }
    }

    // --- fedserve round latency: thread-per-client era vs the reactor ----
    //
    // Whole `simulate_with` runs (connect/accept + 2 rounds + shutdown) at
    // growing connection counts, channel vs TCP loopback. The TCP side is
    // the reactor: ONE server thread multiplexing every socket via
    // poll(2); what used to be a 1 ms sleep-spin over nonblocking reads.
    // Reported throughput is rounds/second; EXPERIMENTS.md §reactor holds
    // the connections-vs-latency table these rows populate.
    println!("\n== fedserve rounds (reactor, 2 rounds/run, d = 4096) ==");
    {
        let rounds = 2usize;
        let d = 4096usize;
        let macro_bench = || Bencher {
            warmup_iters: 0,
            samples: if quick_mode() { 2 } else { 5 },
            iters_per_sample: 1,
            items_per_iter: Some(rounds as f64),
        };
        for n in [8usize, 64, 256] {
            let mut cfg = ExperimentConfig::new("sim", Scheme::TopKUniform, 2, rounds);
            cfg.n_clients = n;
            cfg.server.shards = 4;
            cfg.server.straggler_timeout_ms = 120_000;
            let mb = macro_bench();
            log.push(mb.run(&format!("fedserve 2-round run (channel, n={n})"), || {
                simulate_with(&cfg, d, TransportMode::Channel).unwrap().rounds
            }));
            log.push(mb.run(&format!("fedserve 2-round run (tcp reactor, n={n})"), || {
                simulate_with(&cfg, d, TransportMode::TcpLoopback).unwrap().rounds
            }));
        }
    }

    // --- reactor wakeup cost vs idle connections: poll vs epoll ----------
    //
    // The C100K claim, isolated: one `Poller` holding n idle registered
    // sockets with exactly ONE ready, timed per wakeup (write a byte,
    // wait, drain it). `poll(2)` rebuilds and scans the whole interest
    // set every wait — O(registered) — so its rows grow with n;
    // edge-triggered epoll reports just the ready descriptor — O(ready)
    // — so its rows stay flat. EXPERIMENTS.md §reactor quotes these rows
    // as the wakeup-cost-vs-idle-connections table.
    #[cfg(unix)]
    {
        use std::io::{Read as _, Write as _};
        use std::net::{TcpListener, TcpStream};
        use std::time::Duration;

        use m22::fedserve::reactor::{fd_of, Interest, Poller, Ready};

        println!("\n== reactor wakeup cost (1 ready among n idle connections) ==");
        let soft = pollshim::raise_nofile(2 * 10_000 + 512).unwrap_or(0);
        for n in [256usize, 1_000, 10_000] {
            if (2 * n + 64) as u64 > soft {
                eprintln!("reactor wakeup n={n} skipped (RLIMIT_NOFILE {soft})");
                continue;
            }
            // n loopback pairs; every right end is registered, and only
            // the left end of pair 0 ever speaks
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap();
            let mut left = Vec::with_capacity(n);
            let mut right = Vec::with_capacity(n);
            for _ in 0..n {
                left.push(TcpStream::connect(addr).unwrap());
                right.push(listener.accept().unwrap().0);
            }
            for backend in ["poll", "epoll"] {
                std::env::set_var("M22_POLLER", backend);
                let mut poller = Poller::new();
                std::env::remove_var("M22_POLLER");
                if poller.backend_name() != backend {
                    eprintln!("reactor wakeup ({backend}, n={n}) skipped: backend unavailable");
                    continue;
                }
                for (tok, s) in right.iter().enumerate() {
                    poller.register(tok, fd_of(s), Interest::READ).unwrap();
                }
                let mut ready: Vec<Ready> = Vec::new();
                let mut buf = [0u8; 1];
                let b = Bencher::from_env();
                log.push(b.run(&format!("reactor wakeup ({backend}, n={n} idle)"), || {
                    left[0].write_all(&[1]).unwrap();
                    poller.wait(Some(Duration::from_secs(5)), &mut ready).unwrap();
                    right[0].read_exact(&mut buf).unwrap();
                    ready.len()
                }));
            }
        }
    }

    // --- the collect hot path: O(1) id→slot routing at growing k ---------
    //
    // Whole run_round calls over the channel transport with pre-encoded
    // NoCompression uplinks at a tiny d, so the timing is dominated by the
    // collect loop: poll, frame decode, and sender→slot routing. The old
    // loop did a linear participants scan per uplink (O(k²) per round);
    // the SlotMap makes it one table lookup per event — these rows are the
    // EXPERIMENTS.md evidence that collect cost vs k is now linear.
    println!("\n== fedserve collect path (id→slot routing, d = 256) ==");
    {
        let d = 256usize;
        let spec = sim_spec(d);
        for n in [64usize, 256, 1024] {
            let (mut transport, mut clients) = ChannelTransport::pair(n);
            let mut server = FedServer::new(
                ServerConfig::builder().straggler_timeout_ms(60_000).build(),
                n,
                1,
                Box::new(NoCompression),
            );
            let participants: Vec<usize> = (0..n).collect();
            // one pre-encoded round-0 uplink frame per client
            let frames: Vec<Vec<u8>> = (0..n)
                .map(|id| {
                    let g = vec![0.5f32; d];
                    let (payload, _, report) = encode_once(&NoCompression, &g, &spec).unwrap();
                    wire::encode_update_parts(id, 0, &payload, &report, 0.0)
                })
                .collect();
            let mut w = vec![0.0f32; d];
            let b = Bencher::from_env().throughput(n as f64);
            log.push(b.run(&format!("ps collect+route (n={n})"), || {
                for (c, f) in clients.iter_mut().zip(&frames) {
                    c.send(f).unwrap();
                }
                server.run_round(0, &participants, &mut transport, &spec, &mut w).unwrap().received
            }));
        }
    }

    // --- multi-PS cluster rounds: single PS vs n_ps ∈ {2, 4} -------------
    //
    // Whole simulate_with runs like the reactor section above (2 rounds,
    // channel transport, n = 64 — the comparator row is
    // `fedserve 2-round run (channel, n=64)`), with the round loop hosted
    // by a PsCluster in both partitioning modes. Range mode pays n_ps
    // slice broadcasts per client and a model-parallel reduce; replica
    // mode pays per-subset aggregation plus the eq.-(7) sync.
    println!("\n== fedserve cluster rounds (2 rounds/run, d = 4096, n = 64) ==");
    {
        let rounds = 2usize;
        let d = 4096usize;
        let macro_bench = || Bencher {
            warmup_iters: 0,
            samples: if quick_mode() { 2 } else { 5 },
            iters_per_sample: 1,
            items_per_iter: Some(rounds as f64),
        };
        for (label, mode) in [("range", PsMode::Range), ("replica", PsMode::Replica)] {
            for n_ps in [2usize, 4] {
                let mut cfg = ExperimentConfig::new("sim", Scheme::TopKUniform, 2, rounds);
                cfg.n_clients = 64;
                cfg.server.shards = 4;
                cfg.server.straggler_timeout_ms = 120_000;
                cfg.server.cluster =
                    Some(ClusterConfig::builder().n_ps(n_ps).mode(mode).sync_every(1).build());
                let mb = macro_bench();
                log.push(mb.run(
                    &format!("fedserve 2-round run (cluster {label}, n_ps={n_ps}, n=64)"),
                    || simulate_with(&cfg, d, TransportMode::Channel).unwrap().rounds,
                ));
            }
        }
    }

    // --- peer sub-step wire trip: the per-round cost peering adds --------
    //
    // What `--peers` adds to a lead's round over the in-process cluster is
    // exactly one encode→decode trip per remote member: a range sub-step
    // ships the member's d/n_ps slice plus the round's survivor payloads
    // out and a PeerSlice back; replica mode ships the full-width replica
    // both ways. These rows time that wire trip in isolation (no sockets —
    // the syscall side is already covered by the reactor rows above), so
    // the EXPERIMENTS.md peering table can divide a round's budget into
    // "reduce" vs "membership plumbing". Payload bytes are opaque to the
    // framer, so synthetic survivor payloads time the same copies.
    println!("\n== peer sub-step wire trip (d = 65536, 16 survivor payloads) ==");
    {
        let d = 65_536usize;
        let half = grad(d / 2, 11);
        let full = grad(d, 12);
        let payloads: Vec<Vec<u8>> = (0..16u8).map(|i| vec![i; 8_192]).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        let weights_of = |m: wire::Message| match m {
            wire::Message::PeerRangeStep { weights, .. }
            | wire::Message::PeerSlice { weights, .. }
            | wire::Message::PeerReplicaStep { weights, .. }
            | wire::Message::PeerReplicaSync { weights, .. } => weights.len(),
            _ => panic!("wrong frame kind"),
        };
        let b = Bencher::from_env().throughput((d / 2) as f64);
        log.push(b.run("peer wire range step (d=65536, n_ps=2)", || {
            let f = wire::encode_peer_range_step(3, 0, d, &half, &refs);
            weights_of(wire::decode(&f).unwrap())
        }));
        log.push(b.run("peer wire slice reply (d=65536, n_ps=2)", || {
            let f = wire::encode_peer_slice(3, 0, d, &half);
            weights_of(wire::decode(&f).unwrap())
        }));
        let b = Bencher::from_env().throughput(d as f64);
        log.push(b.run("peer wire replica step (d=65536)", || {
            let f = wire::encode_peer_replica_step(3, &full, &refs);
            weights_of(wire::decode(&f).unwrap())
        }));
        log.push(b.run("peer wire replica sync (d=65536)", || {
            let f = wire::encode_peer_replica_sync(3, &full);
            weights_of(wire::decode(&f).unwrap())
        }));
    }

    // --- fleet event dispatch: n modeled clients, k = 64 sampled ---------
    //
    // Whole simulate_fleet runs: the cost of holding a modeled population
    // of n clients when only k = 64 materialize per round. What scales
    // with n is the scheduler shuffle and the churn-liveness probes; the
    // event heap, sessions, and the reduce are all O(k) — the three rows
    // should be close to flat apart from the O(n) shuffle.
    println!("\n== fleet event dispatch (3 rounds/run, d = 1024, k = 64) ==");
    {
        let rounds = 3usize;
        let d = 1024usize;
        let macro_bench = || Bencher {
            warmup_iters: 0,
            samples: if quick_mode() { 2 } else { 5 },
            iters_per_sample: 1,
            items_per_iter: Some(rounds as f64),
        };
        for n in [10_000usize, 100_000, 1_000_000] {
            let mut cfg = ExperimentConfig::new("sim", Scheme::TopKUniform, 2, rounds);
            cfg.n_clients = n;
            cfg.server.shards = 4;
            cfg.server.sampled_clients = Some(64);
            let scn =
                ScenarioSpec::parse(&format!("fleet:n={n},churn=0.01,lat=lognorm,jitter=0.8"))
                    .unwrap();
            let mb = macro_bench();
            log.push(mb.run(&format!("fleet event dispatch (n={n}, k=64)"), || {
                simulate_fleet(&cfg, &scn, d).unwrap().sim.rounds
            }));
        }
    }

    // --- adaptive fit + re-design: the per-round controller cost ---------
    //
    // One full `observe` per iteration: strided residual sampling (capped
    // at 64k draws, so the cost should be near-flat from 1e5 to 1e6),
    // gennorm + Weibull moment fits, and the (family, m, rq) grid scan
    // with every quantizer table served by the warm LRU cache. This is
    // exactly what `--adaptive` adds to a PS round — the EXPERIMENTS.md
    // adaptive table quotes these rows as the controller overhead.
    println!("\n== adaptive fit+redesign (controller re-selection) ==");
    {
        for d in [100_000usize, 1_000_000] {
            let cfg = ExperimentConfig::new("sim", Scheme::TopKUniform, 2, 1);
            let tables = Arc::new(LruTableCache::new(256));
            let codec: Arc<dyn BlockCodec> = Arc::new(CpuCodec::new());
            let mut ctrl =
                AdaptiveController::new(d, cfg.scheme_spec(d), &cfg.budget(d), codec, tables);
            let w0 = vec![0.0f32; d];
            let w1 = grad(d, 7);
            ctrl.begin_round(&w0);
            // warm the candidate-grid tables so steady-state rounds are timed
            assert!(ctrl.observe(&w1), "fit never landed");
            let b = Bencher::from_env().throughput(d as f64);
            log.push(b.run(&format!("adaptive fit+redesign (d={d})"), || {
                ctrl.observe(&w1) as usize
            }));
        }
    }

    // end-to-end encode/decode (CPU codec path)
    let spec_layout = {
        // VGG-shaped spec straight from the manifest if available, else synthetic
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok().and_then(|m| m.model("vgg_s").ok().cloned())
    };
    if let Some(spec) = &spec_layout {
        let tables = Arc::new(QuantizerTables::new());
        let budget = Budget::paper_point(spec.d(), 2);
        let gg = grad(spec.d(), 2);
        let comp = M22::new(
            M22Config { family: Family::GenNorm, m: 2.0, rq: 2, k: budget.k_ref, min_fit: 512 },
            Arc::new(CpuCodec::new()),
            tables,
        );
        // persistent scratch: the steady-state (allocation-free) encode path
        let mut ctx = EncodeCtx::new();
        // warm the quantizer table so we time the request path, not design
        let _ = comp.encode(&gg, spec, &mut ctx).unwrap();
        let b2 = Bencher::from_env().throughput(spec.d() as f64);
        log.push(b2.run("m22 encode e2e (vgg_s, cpu codec, reused ctx)", || {
            comp.encode(&gg, spec, &mut ctx).unwrap().payload_bytes
        }));
        comp.encode(&gg, spec, &mut ctx).unwrap();
        let payload = ctx.payload().to_vec();
        log.push(b2.run("m22 decode_dense e2e (vgg_s)", || {
            comp.decode_dense(&payload, spec).unwrap().len()
        }));
        let mut acc = vec![0.0f32; spec.d()];
        log.push(b2.run("m22 decode_accumulate e2e (vgg_s)", || {
            comp.decode_accumulate(&payload, spec, 1.0, &mut acc).unwrap();
            acc.len()
        }));
    }

    println!("\n== PJRT boundary (needs artifacts) ==");
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = m22::runtime::spawn(dir.clone()).expect("runtime");
        let man = Manifest::load(&dir).unwrap();
        let ds = m22::data::Dataset::generate(Default::default());
        for arch in ["cnn_s", "resnet_s", "vgg_s"] {
            let w = man.load_init(&dir, arch).unwrap();
            let batch = ds.batch(&ds.train, 0, man.batch);
            let b3 = Bencher {
                warmup_iters: if quick_mode() { 1 } else { 2 },
                samples: if quick_mode() { 3 } else { 8 },
                iters_per_sample: 1,
                items_per_iter: None,
            };
            log.push(b3.run(&format!("pjrt train_step {arch}"), || {
                rt.train_step(arch, &w, &batch.x, &batch.y).unwrap().loss
            }));
        }
        // HLO codec block vs CPU codec block
        let blk = grad(65_536, 3);
        let b4 = Bencher::from_env().throughput(65_536.0);
        log.push(b4.run("hlo quantize 64k block", || rt.quantize(&blk, &t, &c).unwrap().0.len()));
        log.push(b4.run("cpu quantize 64k block", || {
            CpuCodec::new().quantize(&blk, &t, &c).unwrap().0.len()
        }));
        log.push(b4.run("hlo moments 64k block", || rt.moments(&blk).unwrap()[0]));
        log.push(b4.run("cpu moments 64k block", || CpuCodec::new().moments(&blk).unwrap()[0]));
    } else {
        eprintln!("pjrt benches skipped (artifacts not built)");
    }

    match log.write_env() {
        Ok(Some(path)) => eprintln!("wrote {path} ({} bench rows)", log.len()),
        Ok(None) => {}
        Err(e) => panic!("writing BENCH_JSON: {e}"),
    }
}
