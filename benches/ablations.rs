//! Ablations of the design choices DESIGN.md calls out (beyond the paper's
//! figures): error-feedback memory, non-i.i.d. data, partial participation,
//! the optional entropy-coding stage, and quantizer-table snap resolution.
//! `cargo bench --bench ablations`

use std::path::PathBuf;
use std::sync::Arc;

use m22::compress::entropy::{empirical_entropy, entropy_coded_bits};
use m22::compress::{BlockCodec, CpuCodec};
use m22::config::{presets, Scheme};
use m22::coordinator::run_experiment;
use m22::data::Dataset;
use m22::metrics::Recorder;
use m22::quantizer::{design, Family, QuantizerTables};
use m22::stats::{Distribution, GenNorm};
use m22::util::rng::Rng;

fn main() {
    entropy_stage();
    table_snap_resolution();
    federated_ablations();
}

/// How much the optional lossless stage (paper Sec. II-E) would save on
/// real LBG index streams at each rate.
fn entropy_stage() {
    println!("== ablation: entropy-coding stage on LBG index streams ==");
    let dist = GenNorm::standardized(0.8);
    let mut rng = Rng::new(5);
    let samples: Vec<f64> = (0..60_000).map(|_| dist.sample(&mut rng)).collect();
    println!("{:<8} {:>12} {:>12} {:>12} {:>9}", "rate", "nominal", "coded", "entropy", "saving");
    for rq in [1u32, 2, 3, 4] {
        let q = design(&dist, 2.0, 1 << rq);
        let idx: Vec<u32> = samples.iter().map(|&x| q.index_of(x) as u32).collect();
        let nominal = rq as u64 * idx.len() as u64;
        let coded = entropy_coded_bits(&idx, rq);
        let h = empirical_entropy(&idx, rq) * idx.len() as f64;
        println!(
            "R={rq}      {:>12} {:>12} {:>12.0} {:>8.1}%",
            nominal,
            coded,
            h,
            100.0 * (1.0 - coded as f64 / nominal as f64)
        );
    }
}

/// Sensitivity of reconstruction quality to the table snap step (Sec. V-B
/// pre-calculation): finer grids cost more designs but change little.
fn table_snap_resolution() {
    println!("\n== ablation: quantizer-table shape-snap resolution ==");
    let mut rng = Rng::new(9);
    let truth = GenNorm::new(0.01, 0.83); // off-grid shape
    let g: Vec<f32> = (0..50_000).map(|_| truth.sample(&mut rng) as f32).collect();
    let tables = Arc::new(QuantizerTables::new());
    // exact design at the true shape vs snapped table lookups
    let std = (g.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / g.len() as f64).sqrt();
    let mse_of = |q: &m22::quantizer::Quantizer| {
        let qs = q.scaled(std);
        let (t, c) = qs.padded_f32(16);
        let (_, ghat) = CpuCodec::new().quantize(&g, &t, &c).unwrap();
        g.iter().zip(&ghat).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>() / g.len() as f64
    };
    let exact = mse_of(&design(&GenNorm::standardized(0.83), 2.0, 8));
    let snapped = mse_of(&tables.get(Family::GenNorm, 0.83, 2.0, 8)); // snaps to 0.85
    println!(
        "exact-shape design mse {exact:.3e} vs snapped(0.05) {snapped:.3e} ({:+.2}%)",
        100.0 * (snapped / exact - 1.0)
    );
}

/// Federated ablations (need artifacts): memory on/off, non-iid, partial
/// participation — same scheme, same budget, same rounds.
fn federated_ablations() {
    println!("\n== ablation: FL variants (M22 GenNorm M=2 R=2, cnn_s) ==");
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipped (artifacts not built)");
        return;
    }
    let rt = m22::runtime::spawn(dir).expect("runtime");
    let mut base = presets::quickstart("cnn_s", 5);
    base.scheme = Scheme::M22 { family: Family::GenNorm, m: 2.0 };
    base.local_steps = 2;
    base.eval_batches = 2;
    base.n_clients = 4;
    let dataset = Dataset::generate(base.dataset);
    let mut rec = Recorder::new();

    let mut run = |label: &str, f: &dyn Fn(&mut m22::config::ExperimentConfig)| {
        let mut cfg = base.clone();
        f(&mut cfg);
        let out = run_experiment(&cfg, &rt, &dataset, label, &mut rec).expect(label);
        println!(
            "  {label:<28} acc={:.4} loss={:.4}",
            out.final_test_acc, out.final_test_loss
        );
    };
    run("baseline (iid, full part.)", &|_| {});
    run("error-feedback memory", &|c| {
        c.memory = true;
        c.memory_decay = 1.0;
    });
    run("non-iid dirichlet(0.3)", &|c| c.dirichlet_alpha = Some(0.3));
    run("participation 0.5", &|c| c.participation = 0.5);
    run("non-iid + memory", &|c| {
        c.dirichlet_alpha = Some(0.3);
        c.memory = true;
        c.memory_decay = 0.5;
    });
    let _ = &rec;
}
