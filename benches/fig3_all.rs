//! Bench target: regenerate Fig. 3 (all schemes at two budgets) at reduced
//! scale and report wall-clock. `cargo bench --bench fig3_all`
//! For paper-scale curves run `repro fig3 --full --rate {1,3}`.

use std::path::PathBuf;
use std::time::Instant;

use m22::figures::{fig3, FigScale};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("fig3 skipped (artifacts not built)");
        return;
    }
    let rt = m22::runtime::spawn(dir).expect("runtime");
    let mut scale = FigScale::smoke();
    scale.rounds = 4;
    for rq in [1u32, 3] {
        let t0 = Instant::now();
        let (rec, _) = fig3(&rt, rq, scale).expect("fig3");
        println!(
            "fig3 R={rq}: {} series x {} rounds in {:.1}s",
            rec.series_names().len(),
            scale.rounds,
            t0.elapsed().as_secs_f64()
        );
    }
}
