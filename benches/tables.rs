//! Bench target: regenerate Table I and Table II (paper Sec. II-D).
//! `cargo bench --bench tables`

use std::path::PathBuf;

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match m22::train::Manifest::load(&dir) {
        Ok(man) => print!("{}", m22::figures::table1(&man)),
        Err(e) => eprintln!("table1 skipped (artifacts not built): {e:#}"),
    }
    println!();
    print!("{}", m22::figures::table2());
}
