//! Bench target: regenerate Fig. 4 (the effect of M) at reduced scale.
//! `cargo bench --bench fig4_msweep`; paper scale: `repro fig4 --full`.

use std::path::PathBuf;
use std::time::Instant;

use m22::figures::{fig4, FigScale};

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("fig4 skipped (artifacts not built)");
        return;
    }
    let rt = m22::runtime::spawn(dir).expect("runtime");
    let mut scale = FigScale::smoke();
    scale.rounds = 4;
    let t0 = Instant::now();
    let (rec, _) = fig4(&rt, scale).expect("fig4");
    println!(
        "fig4: {} M values x {} rounds in {:.1}s",
        rec.series_names().len(),
        scale.rounds,
        t0.elapsed().as_secs_f64()
    );
}
